//! Streamed (memory-less) transmission medium: projections at 1e5+ modes
//! with **no `[d_in, modes]` slice ever held in memory**.
//!
//! The paper's core scalability claim is that the OPU projects at
//! dimensions "inaccessible to GPUs" because the scattering medium is
//! physical — the transmission matrix is never stored.  The follow-up
//! work (*Hardware Beyond Backpropagation*, arXiv:2012.06373) pushes the
//! same DFA projection to trillion-parameter regimes where materializing
//! the TM is flatly impossible.  [`StreamedMedium`] is the simulator's
//! realization of that property: a projection engine that regenerates TM
//! tiles on the fly from the counter-addressable PCG row streams (see
//! `optics::medium` — row `r`, column `c` is Box–Muller pair `c` of
//! stream `Pcg64::new(seed ^ 0x5eed, r)`, reachable in O(log c) via
//! [`Pcg64::advance`]) and fuses the quadrature accumulation into the
//! tile walk:
//!
//! ```text
//!   for each column tile [c0, c0+w):         (parallel over the pool)
//!       for each active input row r:          (ascending — bit parity)
//!           regenerate (re, im) row-tile into reusable scratch
//!           for each batch sample: p1 += e[b,r]·re ; p2 += e[b,r]·im
//! ```
//!
//! Resident TM bytes are one row-tile of scratch per in-flight tile job
//! — `O(tile_cols)` — instead of `O(d_in × modes)` for the dense slice.
//!
//! **Determinism contract** (pinned in `rust/tests/stream_parity.rs`):
//! for any seed/shape the streamed projection is **bitwise equal** to
//! the materialized one — same entry values (one generation scheme for
//! both backings), same per-output-element accumulation order (ascending
//! input row, zeros skipped — the exact contract `tensor::axpy` keeps
//! with `matmul`), regardless of tile size or pool parallelism (tiles
//! own disjoint output columns; the gather is a pure copy in tile
//! order).  Composed with the farm/service, streamed shards therefore
//! reproduce the dense farm bit for bit under both partitions.
//!
//! **Attribution**: tile generation is *simulation* cost — the physical
//! device pays zero (light does the matmul; the frame clock is the only
//! device time axis).  Each projection charges measured generation
//! seconds to a dedicated [`SimClock`] (sum over tile jobs — capacity
//! accounting, like the farm's device-seconds) and counts tiles/bytes
//! generated, so benches can report the emulation cost separately from
//! the optics frame clock.
//!
//! ## Phase 2: the bounded cross-step tile cache
//!
//! Training regenerates the *same* tiles every step (the matrix never
//! changes — that is the point of the medium).  [`TileCache`] amortizes
//! that: a bounded cache of generated row-tiles keyed by
//! `(seed, row, col0, width)` — absolute medium coordinates plus the
//! generating seed — sized to a byte budget
//! (`--tile-cache-mb`, default off) and — like the stats — shared
//! across every clone/window/shard of the medium, so a farm gets one
//! fleet-wide budget.
//!
//! ### Phase 3 (PR 6): lock stripes + CLOCK recency
//!
//! PR 5's cache was one global `Mutex` around a `HashMap` + `BTreeMap`
//! LRU: every *hit* paid the fleet-wide lock plus an O(log n) recency
//! bump, which profiled as the second serial fraction once generation
//! itself got cheap.  The cache is now **striped**: a power-of-two
//! number of independent lock stripes (`--tile-cache-stripes`, default
//! auto = next pow2 ≥ pool threads), each [`TileKey`] mapped to its
//! stripe by a stable 64-bit mix of the key words, the byte budget
//! apportioned per stripe via [`balanced_widths`].  Within a stripe,
//! recency is **CLOCK (second-chance)**: a hit takes that stripe's
//! lock and sets one `referenced` flag — O(1), no tree — and eviction
//! sweeps a hand that spares referenced slots once before evicting.
//! Concurrent tile jobs on different stripes never contend at all.
//!
//! Cache rules (pinned in `rust/tests/stream_parity.rs`):
//!
//! * **Determinism** — a cached tile is stored exactly as generated, so
//!   cached and uncached projections are **bitwise equal** at any shard
//!   count under either partition, noisy optics included — and the
//!   stripe count is likewise invisible: striped == single-stripe
//!   bitwise (replacement policy and stripe layout decide only *what
//!   is resident*, never what a tile contains).  Hit/miss *counts* are
//!   accounting, not part of the contract: concurrent full-medium
//!   replicas (batch partition) may race to generate the same tile,
//!   and whichever identical copy lands first wins (insert-if-absent
//!   keeps the incumbent).
//! * **Attribution** — cache hits charge **zero** generation
//!   sim-seconds and zero tiles/bytes-generated; misses charge exactly
//!   as before (with a cache attached, the gen clock times the
//!   generation calls themselves; without one, the PR-3 whole-job
//!   timing is unchanged).
//! * **Residency** — the budget counts tile **payload** bytes
//!   (`width × 2 quadratures × 4 B`); each stripe evicts via its CLOCK
//!   hand to stay under its own slice of the budget, and skips any
//!   tile wider than that slice outright (a stripe budget below one
//!   tile therefore caches nothing — costing misses, never bits).
//!   Per-tile bookkeeping (two `Vec` headers, the `Arc` control block,
//!   hash/slot nodes — roughly 200 B/tile) is *not* charged: ~0.6% of
//!   a default 4096-column tile, so size the budget accordingly if you
//!   shrink `tile_cols` far below the default.
//!   [`StreamedMedium::resident_tm_bytes`] includes the full budget,
//!   so the memory-ceiling story (CI `stream-smoke`) covers the cache.
//! * **Metrics** — residency is published per stripe
//!   (`stream_cache_stripe<i>_resident_bytes`) plus the pre-striping
//!   total gauge (`stream_cache_resident_bytes`); the per-stripe names
//!   share no span with the total, so
//!   `Registry::sum_gauges("stream_cache_stripe", "_resident_bytes")`
//!   rolls them up without double-counting the total.
//!
//! [`Pcg64::advance`]: crate::util::rng::Pcg64::advance
//! [`balanced_widths`]: crate::util::balanced_widths

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::time::Instant;

use anyhow::{bail, Context};

use crate::exec::ThreadPool;
use crate::metrics::trace;
use crate::metrics::{Counter, Gauge, Histogram, Registry};
use crate::sim::clock::SimClock;
use crate::tensor::{axpy, matmul, matmul_pooled, Tensor};

use super::medium::TransmissionMatrix;

/// Default column-tile width: 4096 modes × 2 quadratures × 4 B = 32 KiB
/// of scratch per in-flight tile job — cache-friendly and three orders
/// of magnitude under the dense slice at paper scale.
pub const DEFAULT_TILE_COLS: usize = 4096;

/// Metric names for the streamed engine (bound via
/// [`StreamedMedium::with_metrics`]).
pub const STREAM_TILES: &str = "stream_tiles";
pub const STREAM_BYTES: &str = "stream_bytes_generated";
/// Tile-cache hit/miss counters and the resident-bytes gauges (all zero
/// until a [`TileCache`] is attached).
pub const STREAM_CACHE_HITS: &str = "stream_cache_hits";
pub const STREAM_CACHE_MISSES: &str = "stream_cache_misses";
/// Total resident payload bytes across all stripes (the pre-striping
/// gauge name, kept for dashboards that read one number).
pub const STREAM_CACHE_RESIDENT: &str = "stream_cache_resident_bytes";
/// Per-stripe resident gauges are `stream_cache_stripe<i>_resident_bytes`
/// — prefix/suffix chosen so
/// `Registry::sum_gauges(STREAM_CACHE_STRIPE_PREFIX, STREAM_CACHE_STRIPE_SUFFIX)`
/// rolls up exactly the stripes: [`STREAM_CACHE_RESIDENT`] does not
/// start with the stripe prefix, so the total is never double-counted.
pub const STREAM_CACHE_STRIPE_PREFIX: &str = "stream_cache_stripe";
pub const STREAM_CACHE_STRIPE_SUFFIX: &str = "_resident_bytes";

/// Generation-profiling histograms (bound via
/// [`StreamedMedium::with_metrics`], observed per projection only while
/// a trace session is active — `--trace off` keeps the hot path free of
/// extra clocks): nanoseconds spent generating tiles vs servicing
/// cache hits.
pub const STREAM_GEN_NS: &str = "stream_gen_ns";
pub const STREAM_CACHE_HIT_NS: &str = "stream_cache_hit_ns";

/// Gauge name for one stripe's resident payload bytes.
pub fn stream_cache_stripe_gauge_name(stripe: usize) -> String {
    format!("{STREAM_CACHE_STRIPE_PREFIX}{stripe}{STREAM_CACHE_STRIPE_SUFFIX}")
}

#[derive(Default)]
struct StatsInner {
    projections: AtomicU64,
    tiles: AtomicU64,
    bytes_generated: AtomicU64,
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
}

/// Payload bytes of one cached row-tile (both quadratures, f32).
#[inline]
fn tile_bytes(w: usize) -> usize {
    w * 2 * 4
}

/// Key of one cached row-tile in **absolute** medium coordinates
/// (window offsets already applied), so every window/shard sharing a
/// cache agrees on what a tile is.  The generating seed is part of the
/// key: a cache shared across media of *different* seeds (legal through
/// [`StreamedMedium::with_tile_cache`]) can never serve one medium's
/// tiles to another.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
struct TileKey {
    seed: u64,
    row: usize,
    col0: usize,
    w: usize,
}

/// One cached row-tile: both quadratures of `w` columns of one input
/// row, stored exactly as generated — a hit is a bitwise replay.
pub struct CachedTile {
    re: Vec<f32>,
    im: Vec<f32>,
}

/// One slot of a stripe's CLOCK ring.
struct SlotEntry {
    key: TileKey,
    tile: Arc<CachedTile>,
    /// Second-chance flag: set by a hit, cleared (once) by the sweep.
    referenced: bool,
}

struct StripeInner {
    /// key → index into `slots`.
    map: HashMap<TileKey, usize>,
    slots: Vec<SlotEntry>,
    /// CLOCK hand: the next slot the eviction sweep examines.
    hand: usize,
    bytes: usize,
}

/// Bounded striped cache of generated row-tiles — streamed-medium
/// phases 2+3 (see the module docs for the determinism / attribution /
/// residency rules).  `stripes` independent mutexes (power of two),
/// keys assigned by a stable hash; within a stripe a hit is one lock +
/// one flag store (CLOCK second-chance recency — no ordered structure
/// to rebalance), and generation always happens outside any lock.
pub struct TileCache {
    budget: usize,
    /// Per-stripe payload budgets: `balanced_widths(budget, stripes)`.
    stripe_budgets: Vec<usize>,
    stripes: Vec<Mutex<StripeInner>>,
    /// `stripes.len() - 1` (stripe count is a power of two).
    mask: u64,
}

impl TileCache {
    /// A single-stripe cache bounded to `budget` payload bytes (the
    /// pre-striping spelling; behaviorally the PR-5 cache with CLOCK
    /// recency in place of the LRU stamp).
    pub fn with_budget_bytes(budget: usize) -> TileCache {
        Self::with_budget_bytes_striped(budget, 1)
    }

    /// A single-stripe cache bounded to `mb` MiB of tile payload.
    pub fn with_budget_mb(mb: usize) -> TileCache {
        Self::with_budget_bytes(mb * 1024 * 1024)
    }

    /// A cache of `stripes` lock stripes (rounded up to the next power
    /// of two, min 1) sharing `budget` payload bytes, apportioned per
    /// stripe via [`crate::util::balanced_widths`].
    pub fn with_budget_bytes_striped(budget: usize, stripes: usize) -> TileCache {
        let stripes = stripes.max(1).next_power_of_two();
        let stripe_budgets = crate::util::balanced_widths(budget, stripes);
        TileCache {
            budget,
            stripe_budgets,
            stripes: (0..stripes)
                .map(|_| {
                    Mutex::new(StripeInner {
                        map: HashMap::new(),
                        slots: Vec::new(),
                        hand: 0,
                        bytes: 0,
                    })
                })
                .collect(),
            mask: (stripes - 1) as u64,
        }
    }

    /// [`TileCache::with_budget_bytes_striped`] in MiB.
    pub fn with_budget_mb_striped(mb: usize, stripes: usize) -> TileCache {
        Self::with_budget_bytes_striped(mb * 1024 * 1024, stripes)
    }

    /// The payload-byte budget this cache may hold resident across all
    /// stripes (the number [`StreamedMedium::resident_tm_bytes`] folds
    /// in; per-tile bookkeeping overhead is excluded — see the module
    /// docs).
    pub fn budget_bytes(&self) -> usize {
        self.budget
    }

    /// Number of lock stripes (a power of two).
    pub fn stripe_count(&self) -> usize {
        self.stripes.len()
    }

    /// Payload bytes currently resident across all stripes (same
    /// accounting as the budget: tile data only).
    pub fn resident_bytes(&self) -> usize {
        (0..self.stripes.len()).map(|i| self.stripe_resident_bytes(i)).sum()
    }

    /// Payload bytes resident in one stripe (the per-stripe gauge).
    pub fn stripe_resident_bytes(&self, stripe: usize) -> usize {
        let st = self.stripes[stripe].lock().unwrap_or_else(PoisonError::into_inner);
        st.bytes
    }

    /// Tiles currently resident across all stripes.
    pub fn tiles_resident(&self) -> usize {
        self.stripes
            .iter()
            .map(|s| s.lock().unwrap_or_else(PoisonError::into_inner).map.len())
            .sum()
    }

    /// Stable stripe assignment: a 64-bit avalanche mix of the key
    /// words, masked to the stripe count.  Deterministic across runs
    /// and hosts (never `RandomState`), so residency behavior is
    /// reproducible from the seed like everything else.
    fn stripe_of(&self, key: &TileKey) -> usize {
        let mut h: u64 = 0x9E37_79B9_7F4A_7C15;
        for wd in [key.seed, key.row as u64, key.col0 as u64, key.w as u64] {
            h ^= wd;
            h = h.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
            h ^= h >> 33;
        }
        (h & self.mask) as usize
    }

    fn lookup(&self, seed: u64, row: usize, col0: usize, w: usize) -> Option<Arc<CachedTile>> {
        let key = TileKey { seed, row, col0, w };
        let stripe = &self.stripes[self.stripe_of(&key)];
        let mut guard = stripe.lock().unwrap_or_else(PoisonError::into_inner);
        let inner = &mut *guard;
        let &idx = inner.map.get(&key)?;
        let slot = &mut inner.slots[idx];
        slot.referenced = true;
        Some(slot.tile.clone())
    }

    fn insert(&self, seed: u64, row: usize, col0: usize, re: &[f32], im: &[f32]) {
        debug_assert_eq!(re.len(), im.len());
        let entry_bytes = tile_bytes(re.len());
        let key = TileKey { seed, row, col0, w: re.len() };
        let si = self.stripe_of(&key);
        let budget = self.stripe_budgets[si];
        if entry_bytes > budget {
            // A tile wider than this stripe's whole slice can never
            // fit; caching nothing beats evicting everything for
            // nothing.  (With a budget below stripes × tile bytes some
            // or all stripes degenerate to pass-through — misses, not
            // wrong bits.)
            return;
        }
        // Copy the payload and build the Arc BEFORE taking the stripe
        // lock: the critical section stays hash + slot bookkeeping, so
        // a cold first step's parallel misses don't serialize two
        // memcpys each behind a mutex.  (A concurrent duplicate wastes
        // one allocation — rare, and cheaper than lock-held copies
        // always.)
        let tile = Arc::new(CachedTile {
            re: re.to_vec(),
            im: im.to_vec(),
        });
        let mut guard = self.stripes[si].lock().unwrap_or_else(PoisonError::into_inner);
        let inner = &mut *guard;
        if inner.map.contains_key(&key) {
            // A concurrent replica generated it first — identical bits,
            // keep the incumbent.
            return;
        }
        // CLOCK sweep: spare a referenced slot once (clear + advance),
        // evict an unreferenced one in place.  Terminates: every pass
        // over the ring clears flags, and an eviction strictly shrinks
        // `bytes`.
        while inner.bytes + entry_bytes > budget {
            debug_assert!(!inner.slots.is_empty(), "empty stripe over budget");
            if inner.slots.is_empty() {
                break;
            }
            let hand = inner.hand;
            if inner.slots[hand].referenced {
                inner.slots[hand].referenced = false;
                inner.hand = (hand + 1) % inner.slots.len();
            } else {
                let victim = inner.slots.swap_remove(hand);
                inner.map.remove(&victim.key);
                inner.bytes -= tile_bytes(victim.tile.re.len());
                if hand < inner.slots.len() {
                    // The former last slot moved into `hand`; fix its
                    // index and examine it next (no hand advance).
                    *inner.map.get_mut(&inner.slots[hand].key).unwrap() = hand;
                } else {
                    inner.hand = 0;
                }
            }
        }
        let idx = inner.slots.len();
        inner.slots.push(SlotEntry {
            key,
            tile,
            referenced: false,
        });
        inner.map.insert(key, idx);
        inner.bytes += entry_bytes;
    }

    /// Write every resident tile to `path` (atomic via temp + rename) —
    /// the warm-start snapshot `--tile-cache-save` produces.
    ///
    /// Layout (little-endian):
    /// ```text
    /// magic   "LITLTILE"           8 bytes
    /// version u32                  = 1
    /// count   u32
    /// per tile: seed u64, row u64, col0 u64, w u64,
    ///           re f32×w, im f32×w
    /// crc32   u32 over everything above (flate2's crc)
    /// ```
    ///
    /// Tiles are emitted in key order, so two caches holding the same
    /// tiles snapshot to byte-identical files regardless of stripe
    /// layout or insertion history.
    pub fn save_snapshot(&self, path: impl AsRef<std::path::Path>) -> anyhow::Result<()> {
        // Collect under the stripe locks, serialize outside them.
        let mut tiles: Vec<(TileKey, Arc<CachedTile>)> = Vec::new();
        for stripe in &self.stripes {
            let inner = stripe.lock().unwrap_or_else(PoisonError::into_inner);
            tiles.extend(inner.slots.iter().map(|s| (s.key, s.tile.clone())));
        }
        tiles.sort_by_key(|(k, _)| *k);
        let mut buf = Vec::new();
        buf.extend_from_slice(SNAPSHOT_MAGIC);
        buf.extend_from_slice(&SNAPSHOT_VERSION.to_le_bytes());
        buf.extend_from_slice(&(tiles.len() as u32).to_le_bytes());
        for (key, tile) in &tiles {
            for wd in [key.seed, key.row as u64, key.col0 as u64, key.w as u64] {
                buf.extend_from_slice(&wd.to_le_bytes());
            }
            for quad in [&tile.re, &tile.im] {
                for &v in quad.iter() {
                    buf.extend_from_slice(&v.to_le_bytes());
                }
            }
        }
        let mut hasher = flate2::Crc::new();
        hasher.update(&buf);
        buf.extend_from_slice(&hasher.sum().to_le_bytes());

        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let tmp = path.with_extension("tmp");
        {
            use std::io::Write as _;
            let mut f = std::fs::File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            f.write_all(&buf)?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Warm-start from a [`TileCache::save_snapshot`] file, returning
    /// the number of tiles offered to the cache.  Every tile goes
    /// through the ordinary insert path, so the byte budget, stripe
    /// layout and eviction rules hold exactly as if the tiles had been
    /// generated — a snapshot larger than the budget simply stops
    /// sticking.  Keys carry the generating seed, so a foreign
    /// snapshot's tiles can never serve another medium's lookups: they
    /// are misses, not wrong bits.
    pub fn load_snapshot(&self, path: impl AsRef<std::path::Path>) -> anyhow::Result<usize> {
        let path = path.as_ref();
        let buf = std::fs::read(path)
            .with_context(|| format!("reading tile snapshot {}", path.display()))?;
        if buf.len() < 8 + 4 + 4 + 4 {
            bail!("tile snapshot truncated ({} bytes)", buf.len());
        }
        let (body, crc_bytes) = buf.split_at(buf.len() - 4);
        let want_crc = u32::from_le_bytes(crc_bytes.try_into().unwrap());
        let mut hasher = flate2::Crc::new();
        hasher.update(body);
        if hasher.sum() != want_crc {
            bail!("tile snapshot CRC mismatch (corrupt file)");
        }
        fn take<'a>(body: &'a [u8], at: &mut usize, n: usize) -> anyhow::Result<&'a [u8]> {
            if *at + n > body.len() {
                bail!("tile snapshot truncated at byte {at}");
            }
            let s = &body[*at..*at + n];
            *at += n;
            Ok(s)
        }
        fn u64_at(body: &[u8], at: &mut usize) -> anyhow::Result<u64> {
            Ok(u64::from_le_bytes(take(body, at, 8)?.try_into().unwrap()))
        }
        let mut at = 0usize;
        if take(body, &mut at, 8)? != SNAPSHOT_MAGIC {
            bail!("not a litl tile snapshot (bad magic)");
        }
        let version = u32::from_le_bytes(take(body, &mut at, 4)?.try_into().unwrap());
        if version != SNAPSHOT_VERSION {
            bail!("unsupported tile snapshot version {version}");
        }
        let count = u32::from_le_bytes(take(body, &mut at, 4)?.try_into().unwrap()) as usize;
        if count > 1 << 20 {
            bail!("implausible tile count {count}");
        }
        for _ in 0..count {
            let seed = u64_at(body, &mut at)?;
            let row = u64_at(body, &mut at)? as usize;
            let col0 = u64_at(body, &mut at)? as usize;
            let w = u64_at(body, &mut at)? as usize;
            if w == 0 || w > 1 << 24 {
                bail!("implausible tile width {w}");
            }
            let mut quads: [Vec<f32>; 2] = [Vec::new(), Vec::new()];
            for quad in &mut quads {
                quad.try_reserve_exact(w)
                    .map_err(|_| anyhow::anyhow!("tile of {w} columns exceeds memory"))?;
                let raw = take(body, &mut at, w * 4)?;
                quad.extend(
                    raw.chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap())),
                );
            }
            self.insert(seed, row, col0, &quads[0], &quads[1]);
        }
        if at != body.len() {
            bail!("trailing bytes in tile snapshot");
        }
        Ok(count)
    }
}

const SNAPSHOT_MAGIC: &[u8; 8] = b"LITLTILE";
const SNAPSHOT_VERSION: u32 = 1;

/// Snapshot of a streamed medium's lifetime accounting.
#[derive(Clone, Copy, Debug, Default)]
pub struct StreamStats {
    /// Batched projections served.
    pub projections: u64,
    /// Row-tiles regenerated (one per active row per column tile;
    /// cache hits regenerate nothing and are not counted here).
    pub tiles: u64,
    /// Cumulative TM bytes generated (the throughput side of the
    /// "memory-less" trade: regenerated, never resident).
    pub bytes_generated: u64,
    /// Host seconds spent generating tiles, summed over tile jobs.
    pub gen_seconds: f64,
    /// Row-tiles served from the [`TileCache`] (zero without one).
    pub cache_hits: u64,
    /// Row-tiles generated because the attached cache missed.
    pub cache_misses: u64,
    /// Tile payload bytes currently resident in the cache.
    pub cache_resident_bytes: u64,
    /// The cache's byte budget (zero without a cache).
    pub cache_budget_bytes: u64,
}

/// A transmission-matrix window `[d_in, col0 .. col0+modes)` that is
/// never materialized: tiles are regenerated per projection from the
/// counter-addressable row streams.
///
/// Clones (and [`StreamedMedium::split_modes`] shards) share the stats
/// and the generation clock — the fleet view rolls up for free.
#[derive(Clone)]
pub struct StreamedMedium {
    seed: u64,
    d_in: usize,
    /// Start column of this window in the full medium's mode axis.
    col0: usize,
    /// Output modes of this window.
    modes: usize,
    tile_cols: usize,
    /// Optional pool: tile jobs fan out over scoped submit/join.  Results
    /// are bitwise independent of the pool (disjoint column ownership).
    pool: Option<Arc<ThreadPool>>,
    /// Phase-2 cross-step tile cache, shared (like the stats) across
    /// clones/windows/shards.  `None` = regenerate every projection.
    cache: Option<Arc<TileCache>>,
    stats: Arc<StatsInner>,
    gen_clock: SimClock,
    tiles_ctr: Option<Counter>,
    bytes_ctr: Option<Counter>,
    cache_hits_ctr: Option<Counter>,
    cache_misses_ctr: Option<Counter>,
    /// Trace-gated generation profiling ([`STREAM_GEN_NS`] /
    /// [`STREAM_CACHE_HIT_NS`]): per-projection nanoseconds observed
    /// only while a trace session is active.
    gen_ns_hist: Option<Histogram>,
    hit_ns_hist: Option<Histogram>,
    cache_gauge: Option<Gauge>,
    /// One gauge per cache stripe (`stream_cache_stripe<i>_resident_bytes`);
    /// empty until both a registry and a cache are attached (the two
    /// builders compose in either order — each rebinds).
    stripe_gauges: Vec<Gauge>,
    /// Registry handle kept so a cache attached *after*
    /// [`StreamedMedium::with_metrics`] can still bind its stripe
    /// gauges.
    registry: Option<Registry>,
}

/// One tile job's output: its column range of both quadratures plus its
/// generation tallies — row-tiles, bytes, measured generation
/// nanoseconds, measured cache-hit service nanoseconds (zero unless a
/// trace session is active), and cache hits/misses (summed by the
/// single-threaded epilogue, so the accounting is deterministic too).
type TileOut = (Vec<f32>, Vec<f32>, u64, u64, u64, u64, u64, u64);

impl StreamedMedium {
    /// Full-width streamed medium over `modes` output modes.
    pub fn new(seed: u64, d_in: usize, modes: usize) -> Self {
        Self::window(seed, d_in, 0, modes)
    }

    /// A mode window `[col0, col0 + modes)` of the full medium — what a
    /// farm shard sees.  Windows of the same seed are consistent with
    /// each other and with any materialized [`TransmissionMatrix`] of
    /// the same seed (row streams make column prefixes agree).
    pub fn window(seed: u64, d_in: usize, col0: usize, modes: usize) -> Self {
        assert!(d_in > 0 && modes > 0, "streamed medium needs [{d_in}, {modes}] > 0");
        StreamedMedium {
            seed,
            d_in,
            col0,
            modes,
            tile_cols: DEFAULT_TILE_COLS,
            pool: None,
            cache: None,
            stats: Arc::new(StatsInner::default()),
            gen_clock: SimClock::new(),
            tiles_ctr: None,
            bytes_ctr: None,
            cache_hits_ctr: None,
            cache_misses_ctr: None,
            gen_ns_hist: None,
            hit_ns_hist: None,
            cache_gauge: None,
            stripe_gauges: Vec::new(),
            registry: None,
        }
    }

    /// Fan tile jobs out over `pool`'s scoped submit/join.
    pub fn with_pool(mut self, pool: Arc<ThreadPool>) -> Self {
        self.pool = Some(pool);
        self
    }

    /// Override the column-tile width (results are bitwise unchanged;
    /// this only trades scratch size against scheduling granularity).
    pub fn with_tile_cols(mut self, tile_cols: usize) -> Self {
        assert!(tile_cols > 0, "tile_cols must be positive");
        self.tile_cols = tile_cols;
        self
    }

    /// Attach a bounded cross-step [`TileCache`] of `mb` MiB (`0` is
    /// the default-off knob: no cache, identical to today), single
    /// lock stripe.  Clones and windows taken *after* this call share
    /// the cache — one budget for a whole farm.
    pub fn with_tile_cache_mb(self, mb: usize) -> Self {
        self.with_tile_cache_mb_striped(mb, 1)
    }

    /// [`StreamedMedium::with_tile_cache_mb`] with `stripes` lock
    /// stripes (rounded up to a power of two — the
    /// `--tile-cache-stripes` knob lands here).  Striped and
    /// single-stripe caches project identical bits; stripes only cut
    /// lock contention.
    pub fn with_tile_cache_mb_striped(self, mb: usize, stripes: usize) -> Self {
        if mb == 0 {
            return self;
        }
        self.with_tile_cache(Arc::new(TileCache::with_budget_mb_striped(mb, stripes)))
    }

    /// Attach a caller-built (possibly shared) [`TileCache`].
    pub fn with_tile_cache(mut self, cache: Arc<TileCache>) -> Self {
        self.cache = Some(cache);
        self.bind_stripe_gauges();
        self
    }

    /// (Re)create the per-stripe resident gauges once both a registry
    /// and a cache are known; called from whichever of
    /// [`StreamedMedium::with_metrics`] / [`StreamedMedium::with_tile_cache`]
    /// lands second.
    fn bind_stripe_gauges(&mut self) {
        if let (Some(reg), Some(cache)) = (&self.registry, &self.cache) {
            self.stripe_gauges = (0..cache.stripe_count())
                .map(|i| reg.gauge(&stream_cache_stripe_gauge_name(i)))
                .collect();
        }
    }

    /// The attached tile cache, if any.
    pub fn tile_cache(&self) -> Option<&Arc<TileCache>> {
        self.cache.as_ref()
    }

    /// Surface tile/byte generation as [`STREAM_TILES`]/[`STREAM_BYTES`]
    /// counters of `registry`, plus the tile-cache hit/miss counters,
    /// the total resident-bytes gauge and the per-stripe resident
    /// gauges (which stay zero/absent until a cache is attached — the
    /// two builders compose in either order).
    pub fn with_metrics(mut self, registry: &Registry) -> Self {
        self.tiles_ctr = Some(registry.counter(STREAM_TILES));
        self.bytes_ctr = Some(registry.counter(STREAM_BYTES));
        self.cache_hits_ctr = Some(registry.counter(STREAM_CACHE_HITS));
        self.cache_misses_ctr = Some(registry.counter(STREAM_CACHE_MISSES));
        self.gen_ns_hist = Some(registry.histogram(STREAM_GEN_NS));
        self.hit_ns_hist = Some(registry.histogram(STREAM_CACHE_HIT_NS));
        self.cache_gauge = Some(registry.gauge(STREAM_CACHE_RESIDENT));
        self.registry = Some(registry.clone());
        self.bind_stripe_gauges();
        self
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn d_in(&self) -> usize {
        self.d_in
    }

    pub fn modes(&self) -> usize {
        self.modes
    }

    /// Start column of this window in the full medium.
    pub fn col_offset(&self) -> usize {
        self.col0
    }

    /// Bytes the dense backing would hold resident for this window.
    pub fn dense_bytes(&self) -> usize {
        self.d_in * self.modes * 2 * 4
    }

    /// Resident TM bytes per in-flight tile job (one re/im scratch
    /// pair).
    pub fn scratch_bytes_per_job(&self) -> usize {
        self.tile_cols.min(self.modes) * 2 * 4
    }

    /// Peak resident TM bytes for a projection — the memory-less
    /// guarantee as a number benches can assert on.  Accounts for pool
    /// concurrency: with a pool, up to `threads + 1` tile jobs hold
    /// scratch at once (workers plus the helping caller), capped by the
    /// job count.  An attached [`TileCache`] folds its full byte budget
    /// in — the ceiling the cache may grow to is residency this medium
    /// can now hold, and the CI memory-ceiling proof must cover it.
    pub fn resident_tm_bytes(&self) -> usize {
        let tile = self.tile_cols.min(self.modes);
        let n_jobs = self.modes.div_ceil(tile);
        let concurrent = self
            .pool
            .as_ref()
            .map(|p| p.threads() + 1)
            .unwrap_or(1)
            .min(n_jobs);
        let cache_budget = self.cache.as_ref().map(|c| c.budget_bytes()).unwrap_or(0);
        self.scratch_bytes_per_job() * concurrent + cache_budget
    }

    /// Lifetime accounting snapshot.
    pub fn stats(&self) -> StreamStats {
        StreamStats {
            projections: self.stats.projections.load(Ordering::Relaxed),
            tiles: self.stats.tiles.load(Ordering::Relaxed),
            bytes_generated: self.stats.bytes_generated.load(Ordering::Relaxed),
            gen_seconds: self.gen_clock.now_secs(),
            cache_hits: self.stats.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.stats.cache_misses.load(Ordering::Relaxed),
            cache_resident_bytes: self
                .cache
                .as_ref()
                .map(|c| c.resident_bytes() as u64)
                .unwrap_or(0),
            cache_budget_bytes: self
                .cache
                .as_ref()
                .map(|c| c.budget_bytes() as u64)
                .unwrap_or(0),
        }
    }

    /// The generation clock (simulation cost; see module docs).
    pub fn gen_clock(&self) -> &SimClock {
        &self.gen_clock
    }

    /// Partition the window into `shards` contiguous balanced
    /// sub-windows ([`crate::util::balanced_widths`] — one arithmetic
    /// shared with [`TransmissionMatrix::split_modes`] and the service's
    /// row split, so streamed and dense farms carve identical shard
    /// ranges by construction).
    pub fn split_modes(&self, shards: usize) -> Vec<StreamedMedium> {
        assert!(shards >= 1, "need at least one shard");
        assert!(
            shards <= self.modes,
            "cannot split {} modes across {shards} shards",
            self.modes
        );
        let mut out = Vec::with_capacity(shards);
        let mut start = self.col0;
        for width in crate::util::balanced_widths(self.modes, shards) {
            let mut shard = self.clone();
            shard.col0 = start;
            shard.modes = width;
            out.push(shard);
            start += width;
        }
        debug_assert_eq!(start, self.col0 + self.modes);
        out
    }

    /// A contiguous sub-window `[c0, c0 + w)` of this window (columns
    /// relative to it), preserving the pool, tile size and shared stats
    /// — the arbitrary-boundary generalization of
    /// [`StreamedMedium::split_modes`] that weighted/explicit-range
    /// topologies carve shards with.
    pub fn subwindow(&self, c0: usize, w: usize) -> StreamedMedium {
        assert!(
            w > 0 && c0 + w <= self.modes,
            "subwindow [{c0}, {}) out of a {}-mode window",
            c0 + w,
            self.modes
        );
        let mut out = self.clone();
        out.col0 = self.col0 + c0;
        out.modes = w;
        out
    }

    /// Materialize the window as a dense [`TransmissionMatrix`] — the
    /// test oracle (equals `sample(seed, d_in, col0 + modes)` sliced to
    /// the window).  Defeats the whole point at scale; oracle use only.
    pub fn materialize(&self) -> TransmissionMatrix {
        let full = TransmissionMatrix::sample(self.seed, self.d_in, self.col0 + self.modes);
        if self.col0 == 0 {
            full
        } else {
            full.slice_modes(self.col0, self.col0 + self.modes)
        }
    }

    /// Project `[B, d_in]` frames through the window without ever
    /// holding its TM slice: returns `(Re y, Im y)`, each `[B, modes]`,
    /// bitwise equal to `frames @ b_re` / `frames @ b_im` over the
    /// materialized window.
    pub fn project(&self, frames: &Tensor) -> (Tensor, Tensor) {
        assert_eq!(
            frames.cols(),
            self.d_in,
            "streamed projection: frames [{}, {}] vs d_in {}",
            frames.rows(),
            frames.cols(),
            self.d_in
        );
        let b = frames.rows();
        let mut p1 = Tensor::zeros(&[b, self.modes]);
        let mut p2 = Tensor::zeros(&[b, self.modes]);
        if b == 0 {
            return (p1, p2);
        }
        // Dark input rows (zero across the whole batch) contribute no
        // light — their tiles are never generated, mirroring the SLM
        // physics and `matmul`'s per-element zero skip.
        let active: Vec<bool> = (0..self.d_in)
            .map(|r| (0..b).any(|bi| frames.at(bi, r) != 0.0))
            .collect();

        let tile = self.tile_cols.min(self.modes);
        let n_jobs = self.modes.div_ceil(tile);
        let mut slots: Vec<Option<TileOut>> = Vec::with_capacity(n_jobs);
        slots.resize_with(n_jobs, || None);
        match &self.pool {
            Some(pool) => {
                let frames_ref = &*frames;
                let active_ref = &active[..];
                pool.scope(|scope| {
                    for (job, slot) in slots.iter_mut().enumerate() {
                        let this = &*self;
                        scope.submit(move || {
                            let c0 = job * tile;
                            let w = tile.min(this.modes - c0);
                            *slot = Some(this.project_tile(frames_ref, active_ref, c0, w));
                        });
                    }
                });
            }
            None => {
                for (job, slot) in slots.iter_mut().enumerate() {
                    let c0 = job * tile;
                    let w = tile.min(self.modes - c0);
                    *slot = Some(self.project_tile(frames, &active, c0, w));
                }
            }
        }

        // Deterministic gather (tile order == column order) + accounting
        // epilogue on the caller's thread.
        let mut tiles = 0u64;
        let mut bytes = 0u64;
        let mut nanos = 0u64;
        let mut hit_nanos = 0u64;
        let mut hits = 0u64;
        let mut misses = 0u64;
        let mut panicked = 0usize;
        for (job, slot) in slots.into_iter().enumerate() {
            match slot {
                Some((t1, t2, tl, by, ns, hns, hi, mi)) => {
                    let c0 = job * tile;
                    let w = tile.min(self.modes - c0);
                    for bi in 0..b {
                        let dst = bi * self.modes + c0;
                        p1.data_mut()[dst..dst + w]
                            .copy_from_slice(&t1[bi * w..(bi + 1) * w]);
                        p2.data_mut()[dst..dst + w]
                            .copy_from_slice(&t2[bi * w..(bi + 1) * w]);
                    }
                    tiles += tl;
                    bytes += by;
                    nanos += ns;
                    hit_nanos += hns;
                    hits += hi;
                    misses += mi;
                }
                None => panicked += 1,
            }
        }
        assert_eq!(panicked, 0, "streamed projection: {panicked} tile job(s) panicked");
        self.stats.projections.fetch_add(1, Ordering::Relaxed);
        self.stats.tiles.fetch_add(tiles, Ordering::Relaxed);
        self.stats.bytes_generated.fetch_add(bytes, Ordering::Relaxed);
        self.stats.cache_hits.fetch_add(hits, Ordering::Relaxed);
        self.stats.cache_misses.fetch_add(misses, Ordering::Relaxed);
        // Per-tile clock attribution: measured job seconds, summed —
        // capacity accounting like the farm's device-seconds (wall view
        // under a pool is smaller; this is the work done).  Cache hits
        // contributed zero to `nanos` (see `project_tile`).
        self.gen_clock.advance_secs(nanos as f64 / 1e9);
        if let Some(c) = &self.tiles_ctr {
            c.add(tiles);
        }
        if let Some(c) = &self.bytes_ctr {
            c.add(bytes);
        }
        if let Some(c) = &self.cache_hits_ctr {
            c.add(hits);
        }
        if let Some(c) = &self.cache_misses_ctr {
            c.add(misses);
        }
        // Generation profiling: per-projection gen vs hit-service time,
        // observed only while a trace session is active (the same gate
        // that enables the per-row hit clocks in `project_tile`).
        if trace::enabled() {
            if let Some(h) = &self.gen_ns_hist {
                h.observe(nanos as f64);
            }
            if let Some(h) = &self.hit_ns_hist {
                h.observe(hit_nanos as f64);
            }
        }
        if let (Some(g), Some(cache)) = (&self.cache_gauge, &self.cache) {
            // One pass over the stripes: publish each stripe's gauge
            // and the overlap-safe total (the gauges sum to it by
            // construction — `sum_gauges(STREAM_CACHE_STRIPE_PREFIX,
            // STREAM_CACHE_STRIPE_SUFFIX)` gives the same number
            // without reading the total gauge).
            let mut total = 0usize;
            for (i, sg) in self.stripe_gauges.iter().enumerate() {
                let b = cache.stripe_resident_bytes(i);
                sg.set(b as f64);
                total += b;
            }
            if self.stripe_gauges.is_empty() {
                total = cache.resident_bytes();
            }
            g.set(total as f64);
        }
        (p1, p2)
    }

    /// One column tile `[c0, c0 + w)` of the window: fetch or
    /// regenerate each active row's tile and accumulate both
    /// quadratures for the whole batch before moving to the next row
    /// (batch-aware: one generation pass amortizes over all samples).
    /// With a [`TileCache`] attached, hits read the stored tile (bitwise
    /// the generated one) and charge nothing; misses generate into
    /// scratch, store a copy, and charge generation time/tiles/bytes.
    fn project_tile(&self, frames: &Tensor, active: &[bool], c0: usize, w: usize) -> TileOut {
        let job_t0 = Instant::now();
        let b = frames.rows();
        let mut p1 = vec![0.0f32; b * w];
        let mut p2 = vec![0.0f32; b * w];
        // Generation scratch, allocated lazily on the first cache miss:
        // a fully-warm pass (the cache's steady state) never touches it.
        let mut re: Vec<f32> = Vec::new();
        let mut im: Vec<f32> = Vec::new();
        let mut tiles = 0u64;
        let mut gen_nanos = 0u64;
        let mut hit_nanos = 0u64;
        let mut hits = 0u64;
        let mut misses = 0u64;
        // Per-row hit clocks only exist under an active trace session;
        // with tracing off the lookup path takes zero extra `Instant`s.
        let profile_hits = self.cache.is_some() && trace::enabled();
        let col0 = self.col0 + c0;
        for r in 0..self.d_in {
            if !active[r] {
                continue;
            }
            let hit_t0: Option<Instant> = if profile_hits {
                Some(Instant::now())
            } else {
                None
            };
            let cached: Option<Arc<CachedTile>> =
                self.cache.as_ref().and_then(|c| c.lookup(self.seed, r, col0, w));
            let (tile_re, tile_im): (&[f32], &[f32]) = match &cached {
                Some(t) => {
                    hits += 1;
                    if let Some(t0) = hit_t0 {
                        hit_nanos += t0.elapsed().as_nanos() as u64;
                    }
                    (&t.re, &t.im)
                }
                None => {
                    if re.is_empty() {
                        re.resize(w, 0.0);
                        im.resize(w, 0.0);
                    }
                    let gen_t0 = Instant::now();
                    TransmissionMatrix::stream_row_window_into(
                        self.seed,
                        r,
                        col0,
                        &mut re,
                        &mut im,
                    );
                    gen_nanos += gen_t0.elapsed().as_nanos() as u64;
                    tiles += 1;
                    if let Some(cache) = &self.cache {
                        misses += 1;
                        cache.insert(self.seed, r, col0, &re, &im);
                    }
                    (&re, &im)
                }
            };
            for bi in 0..b {
                let s = frames.at(bi, r);
                if s == 0.0 {
                    continue;
                }
                axpy(&mut p1[bi * w..(bi + 1) * w], s, tile_re);
                axpy(&mut p2[bi * w..(bi + 1) * w], s, tile_im);
            }
        }
        // Gen-clock attribution: without a cache this is the PR-3
        // whole-job measurement, unchanged; with one, hits must charge
        // zero gen seconds, so only the measured generation calls count.
        let nanos = if self.cache.is_some() {
            gen_nanos
        } else {
            job_t0.elapsed().as_nanos() as u64
        };
        (p1, p2, tiles, tiles * (w as u64) * 8, nanos, hit_nanos, hits, misses)
    }
}

/// The medium-backing policy, device side: who answers "what does the
/// light do to this frame?"  `Dense` is the classic materialized
/// quadrature tensors; `Streamed` regenerates tiles and never stores
/// the slice.  Both are the *same* matrix for the same seed (one
/// generation scheme — see `optics::medium`), so swapping the backing
/// never changes a single output bit.
#[derive(Clone)]
pub enum Medium {
    Dense(TransmissionMatrix),
    Streamed(StreamedMedium),
}

impl Medium {
    pub fn d_in(&self) -> usize {
        match self {
            Medium::Dense(tm) => tm.d_in,
            Medium::Streamed(sm) => sm.d_in(),
        }
    }

    pub fn modes(&self) -> usize {
        match self {
            Medium::Dense(tm) => tm.modes,
            Medium::Streamed(sm) => sm.modes(),
        }
    }

    // NOTE: deliberately no `seed()` accessor.  A dense shard produced
    // by `slice_modes` keeps its parent's seed but not the column
    // offset, so a bare seed cannot regenerate the shard — exposing it
    // here would invite exactly that bug.  The streamed variant carries
    // its offset ([`StreamedMedium::col_offset`]) and keeps its own
    // accessors.

    /// Human tag for logs/config plumbing.
    pub fn backing_name(&self) -> &'static str {
        match self {
            Medium::Dense(_) => "materialized",
            Medium::Streamed(_) => "streamed",
        }
    }

    /// The dense matrix, when this backing holds one (the HLO projector
    /// and the digital-DFA artifacts need real tensors to pass).
    pub fn dense(&self) -> Option<&TransmissionMatrix> {
        match self {
            Medium::Dense(tm) => Some(tm),
            Medium::Streamed(_) => None,
        }
    }

    /// Peak TM bytes this backing holds resident (streamed: scratch ×
    /// concurrent tile jobs — see [`StreamedMedium::resident_tm_bytes`]).
    pub fn resident_bytes(&self) -> usize {
        match self {
            Medium::Dense(tm) => tm.d_in * tm.modes * 2 * 4,
            Medium::Streamed(sm) => sm.resident_tm_bytes(),
        }
    }

    /// Dense oracle of this medium (clones the tensors for `Dense`;
    /// generates them for `Streamed` — test/oracle use only).
    pub fn materialize(&self) -> TransmissionMatrix {
        match self {
            Medium::Dense(tm) => tm.clone(),
            Medium::Streamed(sm) => sm.materialize(),
        }
    }

    /// Attach a bounded cross-step tile cache to a streamed backing
    /// that does not already carry one (`mb = 0`, a dense backing, or a
    /// caller-attached cache all leave `self` untouched — an existing
    /// cache wins, so the attach is idempotent).  The trainer is the
    /// in-tree attach site (via [`StreamedMedium::with_tile_cache_mb`],
    /// before the topology build carves shard windows); this enum-level
    /// spelling serves callers assembling deployments from a bare
    /// [`Medium`].  Call *before* carving windows/shards: clones share
    /// the cache.
    pub fn with_tile_cache_mb(self, mb: usize) -> Medium {
        self.with_tile_cache_mb_striped(mb, 1)
    }

    /// [`Medium::with_tile_cache_mb`] with `stripes` lock stripes
    /// (rounded up to a power of two) — same idempotence/dense-safety
    /// rules; the stripe count changes contention, never bits.
    pub fn with_tile_cache_mb_striped(self, mb: usize, stripes: usize) -> Medium {
        match self {
            Medium::Streamed(sm) if mb > 0 && sm.tile_cache().is_none() => {
                Medium::Streamed(sm.with_tile_cache_mb_striped(mb, stripes))
            }
            other => other,
        }
    }

    /// Contiguous mode window `[c0, c0 + w)`, preserving the backing —
    /// what [`crate::coordinator::topology::Topology`] carves weighted
    /// or explicit-range shard windows from.  Balanced windows taken
    /// through here are bitwise the [`Medium::split_modes`] slices.
    pub fn window(&self, c0: usize, w: usize) -> Medium {
        match self {
            Medium::Dense(tm) => Medium::Dense(tm.slice_modes(c0, c0 + w)),
            Medium::Streamed(sm) => Medium::Streamed(sm.subwindow(c0, w)),
        }
    }

    /// Contiguous balanced mode windows, preserving the backing — what
    /// the farm's mode partition carves shards from.  Streamed and dense
    /// splits cover identical ranges, so shard outputs agree bit for
    /// bit.
    pub fn split_modes(&self, shards: usize) -> Vec<Medium> {
        match self {
            Medium::Dense(tm) => {
                tm.split_modes(shards).into_iter().map(Medium::Dense).collect()
            }
            Medium::Streamed(sm) => {
                sm.split_modes(shards).into_iter().map(Medium::Streamed).collect()
            }
        }
    }

    /// `(frames @ b_re, frames @ b_im)` under this backing.  `pool`
    /// row-block-parallelizes the dense matmul (bitwise identical to
    /// serial); the streamed backing parallelizes over its own pool if
    /// it was built with one.  All four combinations produce identical
    /// bits.
    pub fn project(&self, frames: &Tensor, pool: Option<&ThreadPool>) -> (Tensor, Tensor) {
        match self {
            Medium::Dense(tm) => match pool {
                Some(p) => (
                    matmul_pooled(frames, &tm.b_re, p),
                    matmul_pooled(frames, &tm.b_im, p),
                ),
                None => (matmul(frames, &tm.b_re), matmul(frames, &tm.b_im)),
            },
            Medium::Streamed(sm) => sm.project(frames),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn tern(rows: usize, cols: usize, seed: u64) -> Tensor {
        let mut rng = Pcg64::seeded(seed);
        let data = (0..rows * cols)
            .map(|_| (rng.next_below(3) as i64 - 1) as f32)
            .collect();
        Tensor::from_vec(&[rows, cols], data)
    }

    #[test]
    fn streamed_is_bitwise_the_dense_projection() {
        for (d_in, modes, b, seed) in
            [(10usize, 64usize, 4usize, 3u64), (17, 130, 1, 9), (33, 4097, 3, 5)]
        {
            let dense = TransmissionMatrix::sample(seed, d_in, modes);
            let sm = StreamedMedium::new(seed, d_in, modes);
            let e = tern(b, d_in, 100 + seed);
            let (s1, s2) = sm.project(&e);
            assert_eq!(s1, matmul(&e, &dense.b_re), "({d_in},{modes},{b})");
            assert_eq!(s2, matmul(&e, &dense.b_im), "({d_in},{modes},{b})");
        }
    }

    #[test]
    fn tile_size_does_not_change_a_bit() {
        let sm = StreamedMedium::new(7, 12, 100);
        let e = tern(5, 12, 1);
        let want = sm.project(&e);
        for tile in [1usize, 3, 7, 64, 100, 4096] {
            let smt = StreamedMedium::new(7, 12, 100).with_tile_cols(tile);
            assert_eq!(smt.project(&e), want, "tile {tile}");
        }
    }

    #[test]
    fn pooled_streamed_is_bitwise_serial_streamed() {
        let pool = Arc::new(ThreadPool::new(4, 16));
        let serial = StreamedMedium::new(11, 20, 300).with_tile_cols(32);
        let pooled = StreamedMedium::new(11, 20, 300)
            .with_tile_cols(32)
            .with_pool(pool);
        let e = tern(6, 20, 2);
        assert_eq!(serial.project(&e), pooled.project(&e));
    }

    #[test]
    fn window_matches_the_dense_column_slice() {
        let dense = TransmissionMatrix::sample(4, 9, 120);
        let e = tern(3, 9, 7);
        for (c0, w) in [(0usize, 120usize), (13, 50), (100, 20)] {
            let sm = StreamedMedium::window(4, 9, c0, w).with_tile_cols(17);
            let slice = dense.slice_modes(c0, c0 + w);
            let (s1, s2) = sm.project(&e);
            assert_eq!(s1, matmul(&e, &slice.b_re), "window {c0}+{w}");
            assert_eq!(s2, matmul(&e, &slice.b_im), "window {c0}+{w}");
        }
    }

    #[test]
    fn split_modes_carves_the_same_shards_as_the_dense_split() {
        let sm = StreamedMedium::new(8, 6, 37);
        let dense = TransmissionMatrix::sample(8, 6, 37);
        for shards in [1usize, 2, 3, 5] {
            let windows = sm.split_modes(shards);
            let slices = dense.split_modes(shards);
            assert_eq!(windows.len(), shards);
            let e = tern(2, 6, 3);
            for (wdw, slc) in windows.iter().zip(&slices) {
                assert_eq!(wdw.modes(), slc.modes);
                let (p1, _) = wdw.project(&e);
                assert_eq!(p1, matmul(&e, &slc.b_re));
            }
        }
    }

    #[test]
    fn subwindow_and_medium_window_match_the_dense_slice() {
        let dense = TransmissionMatrix::sample(12, 7, 60);
        let e = tern(3, 7, 4);
        let sm = StreamedMedium::new(12, 7, 60).with_tile_cols(9);
        for (c0, w) in [(0usize, 60usize), (5, 20), (40, 20)] {
            let sub = sm.subwindow(c0, w);
            let slice = dense.slice_modes(c0, c0 + w);
            let (p1, _) = sub.project(&e);
            assert_eq!(p1, matmul(&e, &slice.b_re), "subwindow {c0}+{w}");
            // The backing-polymorphic window agrees under both backings.
            for medium in [
                Medium::Dense(dense.clone()),
                Medium::Streamed(sm.clone()),
            ] {
                let (w1, _) = medium.window(c0, w).project(&e, None);
                assert_eq!(w1, matmul(&e, &slice.b_re), "window {c0}+{w}");
            }
        }
    }

    #[test]
    fn materialize_is_the_sampled_medium() {
        let sm = StreamedMedium::window(5, 8, 10, 30);
        let oracle = TransmissionMatrix::sample(5, 8, 40).slice_modes(10, 40);
        let got = sm.materialize();
        assert_eq!(got.b_re, oracle.b_re);
        assert_eq!(got.b_im, oracle.b_im);
    }

    #[test]
    fn stats_count_tiles_bytes_and_gen_time() {
        let registry = Registry::new();
        let sm = StreamedMedium::new(2, 10, 100)
            .with_tile_cols(40)
            .with_metrics(&registry);
        // All-ones frames: every row active, 3 column tiles (40/40/20).
        let e = Tensor::from_vec(&[1, 10], vec![1.0; 10]);
        sm.project(&e);
        let st = sm.stats();
        assert_eq!(st.projections, 1);
        assert_eq!(st.tiles, 30, "10 rows × 3 column tiles");
        assert_eq!(st.bytes_generated, (10 * 100 * 2 * 4) as u64);
        assert!(st.gen_seconds > 0.0);
        let snap = registry.snapshot();
        assert_eq!(snap[STREAM_TILES], 30.0);
        assert_eq!(snap[STREAM_BYTES], st.bytes_generated as f64);
        // The memory-less bound: scratch ≪ dense.
        assert!(sm.scratch_bytes_per_job() < sm.dense_bytes());
    }

    #[test]
    fn resident_bytes_account_for_pool_concurrency() {
        // Serial: one job's scratch.  Pooled: workers + helping caller,
        // capped by the job count.
        let serial = StreamedMedium::new(1, 4, 100).with_tile_cols(10);
        assert_eq!(serial.resident_tm_bytes(), serial.scratch_bytes_per_job());
        let pool = Arc::new(ThreadPool::new(3, 16));
        let pooled = StreamedMedium::new(1, 4, 100)
            .with_tile_cols(10)
            .with_pool(pool.clone());
        assert_eq!(
            pooled.resident_tm_bytes(),
            4 * pooled.scratch_bytes_per_job(),
            "3 workers + helping caller"
        );
        // Fewer jobs than threads: capped by jobs.
        let few = StreamedMedium::new(1, 4, 100)
            .with_tile_cols(50)
            .with_pool(pool);
        assert_eq!(few.resident_tm_bytes(), 2 * few.scratch_bytes_per_job());
    }

    #[test]
    fn dark_rows_generate_no_tiles() {
        let sm = StreamedMedium::new(2, 10, 64);
        let mut e = Tensor::zeros(&[2, 10]);
        e.data_mut()[3] = 1.0; // row 3 active in sample 0 only
        sm.project(&e);
        assert_eq!(sm.stats().tiles, 1, "only the one active row");
        // And the result still matches the dense projection exactly.
        let dense = TransmissionMatrix::sample(2, 10, 64);
        let (p1, _) = sm.project(&e);
        assert_eq!(p1, matmul(&e, &dense.b_re));
    }

    #[test]
    fn medium_enum_projects_identically_under_both_backings() {
        let tm = TransmissionMatrix::sample(6, 12, 48);
        let dense = Medium::Dense(tm.clone());
        let streamed = Medium::Streamed(StreamedMedium::new(6, 12, 48));
        let e = tern(4, 12, 8);
        assert_eq!(dense.project(&e, None), streamed.project(&e, None));
        assert_eq!(dense.backing_name(), "materialized");
        assert_eq!(streamed.backing_name(), "streamed");
        assert_eq!(dense.modes(), streamed.modes());
        assert!(streamed.resident_bytes() < dense.resident_bytes());
        assert!(streamed.dense().is_none());
        assert_eq!(streamed.materialize().b_re, tm.b_re);
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let sm = StreamedMedium::new(1, 5, 8);
        let (p1, p2) = sm.project(&Tensor::zeros(&[0, 5]));
        assert_eq!(p1.shape(), &[0, 8]);
        assert_eq!(p2.shape(), &[0, 8]);
        assert_eq!(sm.stats().tiles, 0);
    }

    #[test]
    fn cached_projection_is_bitwise_the_uncached_one() {
        for tile in [7usize, 40, 4096] {
            let plain = StreamedMedium::new(5, 9, 130).with_tile_cols(tile);
            let cached = StreamedMedium::new(5, 9, 130)
                .with_tile_cols(tile)
                .with_tile_cache_mb(4);
            for step in 0..3 {
                let e = tern(4, 9, 50 + step);
                assert_eq!(plain.project(&e), cached.project(&e), "tile {tile} step {step}");
            }
        }
    }

    #[test]
    fn cache_hits_from_the_second_step_and_charge_nothing() {
        let sm = StreamedMedium::new(3, 6, 100)
            .with_tile_cols(40)
            .with_tile_cache_mb(1);
        // All-bright frames: 6 rows × 3 column tiles = 18 row-tiles.
        let e = Tensor::from_vec(&[1, 6], vec![1.0; 6]);
        sm.project(&e);
        let st1 = sm.stats();
        assert_eq!(st1.cache_hits, 0);
        assert_eq!(st1.cache_misses, 18);
        assert_eq!(st1.tiles, 18);
        assert_eq!(st1.cache_resident_bytes, (6 * 100 * 2 * 4) as u64);
        let gen1 = st1.gen_seconds;
        sm.project(&e);
        let st2 = sm.stats();
        assert_eq!(st2.cache_hits, 18, "step 2 serves entirely from cache");
        assert_eq!(st2.cache_misses, 18, "no new misses");
        assert_eq!(st2.tiles, 18, "nothing regenerated");
        assert_eq!(
            st2.bytes_generated, st1.bytes_generated,
            "hits generate zero bytes"
        );
        assert_eq!(st2.gen_seconds, gen1, "hits charge zero gen seconds");
    }

    #[test]
    fn cache_budget_evicts_lru_and_skips_oversized_tiles() {
        // 10-column tiles are 80 B each; a 200 B budget holds 2.
        let cache = TileCache::with_budget_bytes(200);
        let re = vec![1.0f32; 10];
        let im = vec![2.0f32; 10];
        cache.insert(7, 0, 0, &re, &im);
        cache.insert(7, 1, 0, &re, &im);
        assert_eq!(cache.tiles_resident(), 2);
        assert_eq!(cache.resident_bytes(), 160);
        // Touch row 0 so row 1 is the LRU victim.
        assert!(cache.lookup(7, 0, 0, 10).is_some());
        cache.insert(7, 2, 0, &re, &im);
        assert_eq!(cache.tiles_resident(), 2);
        assert!(cache.lookup(7, 0, 0, 10).is_some(), "recently used survives");
        assert!(cache.lookup(7, 1, 0, 10).is_none(), "LRU evicted");
        assert!(cache.lookup(7, 2, 0, 10).is_some());
        // The seed is part of the key: another medium's identical
        // coordinates never hit this one's tiles.
        assert!(cache.lookup(8, 0, 0, 10).is_none(), "cross-seed isolation");
        // A tile wider than the whole budget is never inserted.
        let wide = vec![0.0f32; 100]; // 800 B > 200 B
        cache.insert(7, 9, 0, &wide, &wide);
        assert!(cache.lookup(7, 9, 0, 100).is_none());
        assert_eq!(cache.tiles_resident(), 2);
        // Re-inserting an existing key keeps the incumbent (no growth).
        cache.insert(7, 2, 0, &re, &im);
        assert_eq!(cache.resident_bytes(), 160);
    }

    #[test]
    fn cache_thrash_under_a_too_small_budget_still_matches_bitwise() {
        // Budget for ~1 of 3 tiles per row-walk: cyclic access thrashes
        // the LRU, which must cost only time, never bits.
        let plain = StreamedMedium::new(11, 8, 96).with_tile_cols(32);
        let thrash = StreamedMedium::new(11, 8, 96)
            .with_tile_cols(32)
            .with_tile_cache(Arc::new(TileCache::with_budget_bytes(300)));
        let e = tern(3, 8, 77);
        for step in 0..3 {
            assert_eq!(plain.project(&e), thrash.project(&e), "step {step}");
        }
        let st = thrash.stats();
        assert!(st.cache_resident_bytes <= 300, "budget respected");
    }

    #[test]
    fn windows_and_shards_share_the_cache_and_the_budget_counts_as_resident() {
        let registry = Registry::new();
        let sm = StreamedMedium::new(7, 4, 120)
            .with_tile_cols(30)
            .with_tile_cache_mb(2)
            .with_metrics(&registry);
        assert_eq!(
            sm.resident_tm_bytes(),
            sm.scratch_bytes_per_job() + 2 * 1024 * 1024,
            "cache budget folds into the residency number"
        );
        let shards = sm.split_modes(2);
        let e = tern(2, 4, 9);
        for shard in &shards {
            assert!(
                Arc::ptr_eq(shard.tile_cache().unwrap(), sm.tile_cache().unwrap()),
                "shards share the parent's cache"
            );
            shard.project(&e);
        }
        // Second pass over the shards hits what the first pass cached.
        let before = sm.stats().cache_hits;
        for shard in &shards {
            shard.project(&e);
        }
        let st = sm.stats();
        assert!(st.cache_hits > before, "cross-shard second pass hits");
        let snap = registry.snapshot();
        assert_eq!(snap[STREAM_CACHE_HITS], st.cache_hits as f64);
        assert_eq!(snap[STREAM_CACHE_MISSES], st.cache_misses as f64);
        assert_eq!(snap[STREAM_CACHE_RESIDENT], st.cache_resident_bytes as f64);
        // The subwindow path (weighted/explicit topologies) shares too.
        let sub = sm.subwindow(10, 50);
        assert!(Arc::ptr_eq(sub.tile_cache().unwrap(), sm.tile_cache().unwrap()));
    }

    #[test]
    fn striped_cache_is_bitwise_single_stripe_at_every_stripe_count() {
        // The PR-6 core contract: stripes decide contention and
        // residency layout, never a single output bit — cached results
        // are stored exactly as generated under any policy.
        let plain = StreamedMedium::new(9, 7, 200).with_tile_cols(32);
        let e = tern(3, 7, 21);
        let want: Vec<_> = (0..3).map(|_| plain.project(&e)).collect();
        for stripes in [1usize, 2, 4, 8] {
            let striped = StreamedMedium::new(9, 7, 200)
                .with_tile_cols(32)
                .with_tile_cache(Arc::new(TileCache::with_budget_mb_striped(2, stripes)));
            assert_eq!(striped.tile_cache().unwrap().stripe_count(), stripes);
            for (step, w) in want.iter().enumerate() {
                assert_eq!(&striped.project(&e), w, "stripes {stripes} step {step}");
            }
            let st = striped.stats();
            assert!(
                st.cache_resident_bytes <= st.cache_budget_bytes,
                "stripes {stripes}: resident within budget"
            );
        }
    }

    #[test]
    fn stripe_count_rounds_up_to_a_power_of_two() {
        for (ask, got) in [(0usize, 1usize), (1, 1), (2, 2), (3, 4), (5, 8), (8, 8), (9, 16)] {
            let c = TileCache::with_budget_bytes_striped(1024, ask);
            assert_eq!(c.stripe_count(), got, "ask {ask}");
        }
    }

    #[test]
    fn stripe_budget_below_one_tile_caches_nothing_but_stays_bitwise() {
        // 400 B total over 8 stripes = 50 B per stripe; a 32-column
        // tile is 256 B — wider than every stripe's slice, so nothing
        // is ever resident.  Costs misses, never bits.
        let cache = Arc::new(TileCache::with_budget_bytes_striped(400, 8));
        let plain = StreamedMedium::new(13, 6, 96).with_tile_cols(32);
        let starved = StreamedMedium::new(13, 6, 96)
            .with_tile_cols(32)
            .with_tile_cache(cache.clone());
        let e = tern(2, 6, 5);
        for step in 0..2 {
            assert_eq!(plain.project(&e), starved.project(&e), "step {step}");
        }
        assert_eq!(cache.tiles_resident(), 0, "no stripe can fit a tile");
        assert_eq!(cache.resident_bytes(), 0);
        let st = starved.stats();
        assert_eq!(st.cache_hits, 0);
        assert!(st.cache_misses > 0, "every lookup missed");
    }

    #[test]
    fn oversized_tile_skip_is_per_stripe() {
        // 1 KiB over 4 stripes = 256 B per stripe: a 4-column tile
        // (32 B) fits even if hashing piles all eight onto one stripe,
        // while a 40-column tile (320 B) fits no stripe — even though
        // 320 B < the 1 KiB total.
        let cache = TileCache::with_budget_bytes_striped(1024, 4);
        let (re_s, im_s) = (vec![1.0f32; 4], vec![2.0f32; 4]);
        let (re_l, im_l) = (vec![3.0f32; 40], vec![4.0f32; 40]);
        for row in 0..8 {
            cache.insert(5, row, 0, &re_s, &im_s);
            cache.insert(5, row, 64, &re_l, &im_l);
        }
        assert_eq!(cache.tiles_resident(), 8, "all small tiles, no large ones");
        for row in 0..8 {
            assert!(cache.lookup(5, row, 0, 4).is_some());
            assert!(cache.lookup(5, row, 64, 40).is_none(), "row {row} skipped");
        }
    }

    #[test]
    fn insert_if_absent_keeps_the_incumbent_across_stripes() {
        // The concurrent-replica race rule holds per stripe: whoever
        // lands first wins, a second identical-key insert is a no-op.
        let cache = TileCache::with_budget_bytes_striped(64 * 1024, 4);
        let first = vec![1.0f32; 16];
        let second = vec![9.0f32; 16];
        for row in 0..32 {
            cache.insert(3, row, 0, &first, &first);
        }
        let bytes = cache.resident_bytes();
        for row in 0..32 {
            cache.insert(3, row, 0, &second, &second);
        }
        assert_eq!(cache.resident_bytes(), bytes, "re-insert never grows");
        for row in 0..32 {
            let t = cache.lookup(3, row, 0, 16).unwrap();
            assert_eq!(t.re[0].to_bits(), 1.0f32.to_bits(), "row {row} incumbent");
        }
    }

    #[test]
    fn snapshot_warm_start_replays_bitwise_with_zero_generation() {
        let path = std::env::temp_dir().join("litl_tiles_warm_test.tiles");
        let src = StreamedMedium::new(21, 6, 96)
            .with_tile_cols(32)
            .with_tile_cache_mb(2);
        let e = tern(2, 6, 41);
        let want = src.project(&e);
        src.tile_cache().unwrap().save_snapshot(&path).unwrap();
        // A fresh process's cache warm-starts from the snapshot: the
        // same projection is bitwise identical and generates NOTHING —
        // zero tiles, zero bytes, zero generation sim-seconds.
        let dst = StreamedMedium::new(21, 6, 96)
            .with_tile_cols(32)
            .with_tile_cache_mb(2);
        let n = dst.tile_cache().unwrap().load_snapshot(&path).unwrap();
        assert!(n > 0, "snapshot carried tiles");
        assert_eq!(dst.project(&e), want, "warm replay is bitwise");
        let st = dst.stats();
        assert_eq!(st.tiles, 0, "nothing regenerated");
        assert_eq!(st.bytes_generated, 0);
        assert_eq!(st.gen_seconds, 0.0, "zero generation sim-seconds");
        assert_eq!(st.cache_misses, 0);
        assert!(st.cache_hits > 0);
        // A foreign medium (different seed) loading the same snapshot
        // gets misses, never wrong bits: the seed is part of the key.
        let foreign = StreamedMedium::new(99, 6, 96)
            .with_tile_cols(32)
            .with_tile_cache_mb(2);
        foreign.tile_cache().unwrap().load_snapshot(&path).unwrap();
        let plain = StreamedMedium::new(99, 6, 96).with_tile_cols(32);
        assert_eq!(foreign.project(&e), plain.project(&e));
        assert_eq!(foreign.stats().cache_hits, 0, "cross-seed isolation");
    }

    #[test]
    fn snapshot_bytes_are_stripe_independent_and_corruption_is_loud() {
        // Same tiles through different stripe layouts snapshot to
        // byte-identical files (tiles are emitted in key order).
        let (re, im) = (vec![1.5f32; 8], vec![-2.5f32; 8]);
        let a = TileCache::with_budget_bytes_striped(64 * 1024, 1);
        let b = TileCache::with_budget_bytes_striped(64 * 1024, 8);
        for row in 0..12 {
            a.insert(4, 11 - row, 0, &re, &im);
            b.insert(4, row, 0, &re, &im);
        }
        let pa = std::env::temp_dir().join("litl_tiles_a_test.tiles");
        let pb = std::env::temp_dir().join("litl_tiles_b_test.tiles");
        a.save_snapshot(&pa).unwrap();
        b.save_snapshot(&pb).unwrap();
        assert_eq!(std::fs::read(&pa).unwrap(), std::fs::read(&pb).unwrap());
        // Loading honors the byte budget through the ordinary insert
        // path: a small cache keeps at most its budget resident.
        let small = TileCache::with_budget_bytes(128);
        small.load_snapshot(&pa).unwrap();
        assert!(small.resident_bytes() <= 128);
        // Corruption and truncation are loud, never wrong tiles.
        let mut bytes = std::fs::read(&pa).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        std::fs::write(&pb, &bytes).unwrap();
        let err = TileCache::with_budget_mb(1)
            .load_snapshot(&pb)
            .unwrap_err()
            .to_string();
        assert!(err.contains("CRC"), "{err}");
        let good = std::fs::read(&pa).unwrap();
        std::fs::write(&pb, &good[..good.len() - 7]).unwrap();
        assert!(TileCache::with_budget_mb(1).load_snapshot(&pb).is_err());
        std::fs::write(&pb, b"not a tile snapshot").unwrap();
        assert!(TileCache::with_budget_mb(1).load_snapshot(&pb).is_err());
    }

    #[test]
    fn concurrent_replicas_thrashing_a_striped_cache_stay_bitwise() {
        // Batch-partition shape: N full-medium replicas share one
        // under-sized striped cache and race insert/evict across steps.
        // Every replica must still produce the uncached bits.
        let oracle = StreamedMedium::new(17, 8, 128).with_tile_cols(16);
        let e = tern(3, 8, 33);
        let want = oracle.project(&e);
        let cache = Arc::new(TileCache::with_budget_bytes_striped(600, 4));
        let replicas: Vec<StreamedMedium> = (0..4)
            .map(|_| {
                StreamedMedium::new(17, 8, 128)
                    .with_tile_cols(16)
                    .with_tile_cache(cache.clone())
            })
            .collect();
        std::thread::scope(|s| {
            for sm in &replicas {
                let want = &want;
                let e = &e;
                s.spawn(move || {
                    for _ in 0..5 {
                        assert_eq!(&sm.project(e), want);
                    }
                });
            }
        });
        assert!(cache.resident_bytes() <= 600, "budget respected under race");
    }

    #[test]
    fn per_stripe_gauges_roll_up_to_the_total_without_double_count() {
        let registry = Registry::new();
        let sm = StreamedMedium::new(4, 5, 120)
            .with_tile_cols(20)
            .with_metrics(&registry)
            .with_tile_cache(Arc::new(TileCache::with_budget_mb_striped(1, 4)));
        let e = Tensor::from_vec(&[1, 5], vec![1.0; 5]);
        sm.project(&e);
        let cache = sm.tile_cache().unwrap();
        let resident = cache.resident_bytes();
        assert!(resident > 0, "something cached");
        let snap = registry.snapshot();
        // Every stripe publishes; the stripes sum to the total gauge
        // AND to the overlap-safe sum_gauges roll-up (which must not
        // also pick up the total gauge — that is the double-count the
        // stripe prefix exists to prevent).
        let stripe_sum: f64 = (0..cache.stripe_count())
            .map(|i| snap[&stream_cache_stripe_gauge_name(i)])
            .sum();
        assert_eq!(stripe_sum, resident as f64);
        assert_eq!(snap[STREAM_CACHE_RESIDENT], resident as f64);
        assert_eq!(
            registry.sum_gauges(STREAM_CACHE_STRIPE_PREFIX, STREAM_CACHE_STRIPE_SUFFIX),
            resident as f64,
            "roll-up sees exactly the stripes, not the total gauge too"
        );
        // Builder order composes: cache first, metrics second.
        let registry2 = Registry::new();
        let sm2 = StreamedMedium::new(4, 5, 120)
            .with_tile_cols(20)
            .with_tile_cache(Arc::new(TileCache::with_budget_mb_striped(1, 2)))
            .with_metrics(&registry2);
        sm2.project(&e);
        assert_eq!(
            registry2.sum_gauges(STREAM_CACHE_STRIPE_PREFIX, STREAM_CACHE_STRIPE_SUFFIX),
            registry2.snapshot()[STREAM_CACHE_RESIDENT],
            "either builder order binds the stripe gauges"
        );
    }

    #[test]
    fn medium_with_tile_cache_mb_is_idempotent_and_dense_safe() {
        let dense = Medium::Dense(TransmissionMatrix::sample(2, 4, 8));
        assert!(matches!(dense.with_tile_cache_mb(8), Medium::Dense(_)));
        let streamed = Medium::Streamed(StreamedMedium::new(2, 4, 8)).with_tile_cache_mb(8);
        let Medium::Streamed(sm) = &streamed else {
            panic!("backing changed")
        };
        let first = Arc::clone(sm.tile_cache().unwrap());
        // A second attach keeps the existing cache (caller's cache wins).
        let again = streamed.with_tile_cache_mb(16);
        let Medium::Streamed(sm2) = &again else {
            panic!("backing changed")
        };
        assert!(Arc::ptr_eq(sm2.tile_cache().unwrap(), &first));
        assert_eq!(sm2.tile_cache().unwrap().budget_bytes(), 8 * 1024 * 1024);
        // mb = 0 is the off switch.
        let off = Medium::Streamed(StreamedMedium::new(2, 4, 8)).with_tile_cache_mb(0);
        let Medium::Streamed(sm3) = &off else {
            panic!("backing changed")
        };
        assert!(sm3.tile_cache().is_none());
    }
}
