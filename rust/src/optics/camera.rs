//! Camera model: interference intensity, photon noise, 8-bit ADC.
//!
//! Mirrors the L1 Pallas `camera_intensity` kernel bit-for-physics (the
//! rust-native device and the HLO `opu_project` artifact must agree —
//! cross-checked in `rust/tests/optics_parity.rs`):
//!
//! ```text
//! I(p)  = (y_re(p) + A·cos kp)² + (y_im(p) + A·sin kp)²
//! I'(p) = I + √(I/n_ph)·ξ₁ + σ_r·ξ₂
//! count = clip(round(I'/gain), 0, 255)
//! ```

use crate::util::rng::Pcg64;

/// Static camera geometry/sensitivity for a frame size.
#[derive(Clone, Debug)]
pub struct Camera {
    pub npix: usize,
    pub amp: f64,
    pub gain: f64,
    /// Precomputed carrier phases cos(k·p), sin(k·p).
    cosk: Vec<f32>,
    sink: Vec<f32>,
}

impl Camera {
    pub fn new(npix: usize, carrier: f64, amp: f64, gain: f64) -> Self {
        let mut cosk = vec![0.0f32; npix];
        let mut sink = vec![0.0f32; npix];
        for p in 0..npix {
            let ph = carrier * p as f64;
            cosk[p] = ph.cos() as f32;
            sink[p] = ph.sin() as f32;
        }
        Camera {
            npix,
            amp,
            gain,
            cosk,
            sink,
        }
    }

    /// Expose one frame: pixel-mapped signal field quadratures in,
    /// quantized ADC counts out.  `n_ph <= 0` disables shot noise.
    pub fn expose(
        &self,
        yre_pix: &[f32],
        yim_pix: &[f32],
        n_ph: f32,
        read_sigma: f32,
        rng: &mut Pcg64,
        counts: &mut [f32],
    ) {
        debug_assert_eq!(yre_pix.len(), self.npix);
        debug_assert_eq!(counts.len(), self.npix);
        let amp = self.amp as f32;
        let inv_gain = 1.0 / self.gain as f32;
        for p in 0..self.npix {
            let fre = yre_pix[p] + amp * self.cosk[p];
            let fim = yim_pix[p] + amp * self.sink[p];
            let mut intensity = fre * fre + fim * fim;
            if n_ph > 0.0 {
                intensity += (intensity.max(0.0) / n_ph).sqrt() * rng.next_normal_f32();
            }
            if read_sigma > 0.0 {
                intensity += read_sigma * rng.next_normal_f32();
            }
            counts[p] = (intensity * inv_gain).round().clamp(0.0, 255.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quiet_expose(cam: &Camera, yre: &[f32], yim: &[f32]) -> Vec<f32> {
        let mut rng = Pcg64::seeded(0);
        let mut out = vec![0.0; cam.npix];
        cam.expose(yre, yim, -1.0, 0.0, &mut rng, &mut out);
        out
    }

    #[test]
    fn dark_frame_is_reference_only() {
        let cam = Camera::new(16, std::f64::consts::FRAC_PI_2, 4.0, 1.0);
        let z = vec![0.0f32; 16];
        let counts = quiet_expose(&cam, &z, &z);
        // |A e^{ikp}|² = A² = 16 everywhere.
        assert!(counts.iter().all(|&c| (c - 16.0).abs() < 0.51), "{counts:?}");
    }

    #[test]
    fn quantization_and_clipping() {
        let cam = Camera::new(8, std::f64::consts::FRAC_PI_2, 100.0, 1.0);
        let z = vec![0.0f32; 8];
        let counts = quiet_expose(&cam, &z, &z);
        // A² = 10000 ≫ 255·gain → saturates.
        assert!(counts.iter().all(|&c| c == 255.0));
    }

    #[test]
    fn shot_noise_scales_inverse_sqrt_photons() {
        let cam = Camera::new(4096, std::f64::consts::FRAC_PI_2, 16.0, 1.0);
        let z = vec![0.0f32; 4096];
        let noise_std = |n_ph: f32, seed: u64| {
            let mut rng = Pcg64::seeded(seed);
            let mut out = vec![0.0; 4096];
            cam.expose(&z, &z, n_ph, 0.0, &mut rng, &mut out);
            // intensity is flat 256; spread = shot noise (+quantization)
            let mean: f32 = out.iter().sum::<f32>() / 4096.0;
            (out.iter().map(|&c| (c - mean).powi(2)).sum::<f32>() / 4096.0).sqrt()
        };
        let lo = noise_std(16.0, 1); // √(256/16) = 4 counts
        let hi = noise_std(1024.0, 2); // √(256/1024) = 0.5 counts
        assert!(lo > 2.0 * hi, "lo={lo} hi={hi}");
    }

    #[test]
    fn interference_term_present() {
        // A pure real signal on pixel phases 0 and π should move counts
        // in opposite directions: I = (y ± A)² + 0.
        let cam = Camera::new(4, std::f64::consts::FRAC_PI_2, 4.0, 1.0);
        let yre = vec![1.0f32; 4];
        let yim = vec![0.0f32; 4];
        let counts = quiet_expose(&cam, &yre, &yim);
        // p=0: (1+4)² = 25;  p=2: (1-4)² = 9.
        assert_eq!(counts[0], 25.0);
        assert_eq!(counts[2], 9.0);
    }
}
