//! SLM input encoding: ternary frames + device imperfections.
//!
//! The paper quantizes the error vector to {-1, 0, +1} (Eq. 4) because
//! the OPU's input device — a DMD-backed SLM — displays binary/ternary
//! amplitude patterns.  This module validates/encodes frames and models
//! two device imperfections used by the failure-injection tests:
//! stuck ("dead") input pixels and whole-frame drops.

use anyhow::{bail, Result};

use crate::tensor::Tensor;
use crate::util::rng::Pcg64;

/// SLM encoder for `[batch, d_in]` ternary frames.
#[derive(Clone, Debug)]
pub struct Slm {
    pub d_in: usize,
    /// Stuck-at-zero input pixels (indices into 0..d_in).
    dead_pixels: Vec<usize>,
    /// Probability a whole frame is dropped (camera sync slip).
    drop_prob: f32,
}

impl Slm {
    pub fn new(d_in: usize) -> Self {
        Slm {
            d_in,
            dead_pixels: Vec::new(),
            drop_prob: 0.0,
        }
    }

    /// Failure injection: mark pixels stuck at zero.
    pub fn with_dead_pixels(mut self, pixels: Vec<usize>) -> Self {
        assert!(pixels.iter().all(|&p| p < self.d_in));
        self.dead_pixels = pixels;
        self
    }

    /// Failure injection: drop frames with probability `p`.
    pub fn with_drop_prob(mut self, p: f32) -> Self {
        assert!((0.0..=1.0).contains(&p));
        self.drop_prob = p;
        self
    }

    /// Validate + encode a batch of ternary frames.  Returns the frames
    /// actually displayed (dead pixels zeroed) and a per-frame "displayed"
    /// mask (false = dropped, caller must retry those frames).
    pub fn encode(&self, frames: &Tensor, rng: &mut Pcg64) -> Result<(Tensor, Vec<bool>)> {
        if frames.shape().len() != 2 || frames.cols() != self.d_in {
            bail!(
                "SLM: expected [batch, {}], got {:?}",
                self.d_in,
                frames.shape()
            );
        }
        for &v in frames.data() {
            if v != 0.0 && v != 1.0 && v != -1.0 {
                bail!("SLM: non-ternary value {v} (quantize with Eq. 4 first)");
            }
        }
        let mut shown = frames.clone();
        if !self.dead_pixels.is_empty() {
            let cols = shown.cols();
            for r in 0..shown.rows() {
                for &p in &self.dead_pixels {
                    shown.data_mut()[r * cols + p] = 0.0;
                }
            }
        }
        let displayed: Vec<bool> = (0..frames.rows())
            .map(|_| self.drop_prob == 0.0 || rng.next_f32() >= self.drop_prob)
            .collect();
        Ok((shown, displayed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tern(rows: usize, cols: usize, seed: u64) -> Tensor {
        let mut rng = Pcg64::seeded(seed);
        let data = (0..rows * cols)
            .map(|_| (rng.next_below(3) as i64 - 1) as f32)
            .collect();
        Tensor::from_vec(&[rows, cols], data)
    }

    #[test]
    fn accepts_ternary_rejects_float() {
        let slm = Slm::new(8);
        let mut rng = Pcg64::seeded(0);
        let ok = tern(3, 8, 1);
        assert!(slm.encode(&ok, &mut rng).is_ok());

        let mut bad = ok.clone();
        bad.data_mut()[5] = 0.3;
        assert!(slm.encode(&bad, &mut rng).is_err());
    }

    #[test]
    fn rejects_wrong_width() {
        let slm = Slm::new(8);
        let mut rng = Pcg64::seeded(0);
        assert!(slm.encode(&tern(2, 7, 0), &mut rng).is_err());
    }

    #[test]
    fn dead_pixels_are_zeroed() {
        let slm = Slm::new(4).with_dead_pixels(vec![1, 3]);
        let mut rng = Pcg64::seeded(0);
        let frames = Tensor::from_vec(&[2, 4], vec![1., 1., -1., -1., 1., -1., 1., 1.]);
        let (shown, _) = slm.encode(&frames, &mut rng).unwrap();
        assert_eq!(shown.row(0), &[1., 0., -1., 0.]);
        assert_eq!(shown.row(1), &[1., 0., 1., 0.]);
    }

    #[test]
    fn drop_prob_statistics() {
        let slm = Slm::new(4).with_drop_prob(0.25);
        let mut rng = Pcg64::seeded(7);
        let frames = tern(2000, 4, 2);
        let (_, displayed) = slm.encode(&frames, &mut rng).unwrap();
        let dropped = displayed.iter().filter(|&&d| !d).count();
        let rate = dropped as f32 / 2000.0;
        assert!((rate - 0.25).abs() < 0.05, "drop rate {rate}");
    }
}
