//! Analytic device models: timing and energy.
//!
//! E2 (throughput) and E3 (power efficiency) compare the OPU against
//! digital hardware *at scales this sandbox cannot execute* (the paper's
//! 1e5-dimensional projections at 1.5 kHz, hundred-billion-parameter
//! regimes).  Numerics are validated at executable scale by the optics
//! and runtime modules; these models extrapolate the *timing/energy*
//! dimension, with every constant documented and sourced either from the
//! paper (OPU) or from public datasheets (V100 GPU, desktop CPU).

pub mod clock;
pub mod power;
