//! Energy/throughput models for the E2/E3/E4 comparisons.
//!
//! Sources for the constants:
//!
//! * **OPU** — the paper §III + Perspectives: 1500 frames/s, output size
//!   up to ~1e5 (off-axis) or ~1e6 (phase-shifting), input up to ~1e6
//!   (DMD), ~30 W total draw, throughput *independent* of matrix size
//!   (the projection happens in light propagation).
//! * **GPU** — NVIDIA V100 (the 2020 contemporary): 15.7 TFLOP/s fp32
//!   peak, 900 GB/s HBM2, 300 W TDP, 32 GB memory, ~10 µs kernel-launch
//!   overhead.  A random projection `B @ e` with a *stored* matrix is
//!   bandwidth-bound (each weight byte is touched once per use), which is
//!   the honest regime for DFA feedback (a new error vector per step).
//! * **CPU** — this sandbox's single core, measured by the bench harness
//!   and passed in (`CpuModel::measured`).

/// The simulated photonic co-processor's timing/energy envelope.
#[derive(Clone, Copy, Debug)]
pub struct OpuModel {
    pub frame_rate_hz: f64,
    pub power_watts: f64,
    /// Max output modes for the active holography scheme.
    pub max_output: usize,
    /// Max input dimension (DMD pixels).
    pub max_input: usize,
}

/// Holography scheme (E4: Perspectives scaling).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Holography {
    /// Off-axis: carrier fringes cost ~4 camera pixels per output mode.
    OffAxis,
    /// Phase-shifting: 1 pixel per mode, ~3 frames per projection.
    PhaseShifting,
}

impl OpuModel {
    /// Paper-configured device for a holography scheme.
    pub fn paper(scheme: Holography) -> Self {
        match scheme {
            Holography::OffAxis => OpuModel {
                frame_rate_hz: 1500.0,
                power_watts: 30.0,
                max_output: 100_000,   // paper: "about 1e5"
                max_input: 1_000_000,  // DMD ~1 Mpixel
            },
            Holography::PhaseShifting => OpuModel {
                // 3 phase-stepped frames per projection
                frame_rate_hz: 1500.0 / 3.0,
                power_watts: 30.0,
                max_output: 1_000_000, // paper: "up to 1e6"
                max_input: 1_000_000,
            },
        }
    }

    /// Whether a (d_in → d_out) projection fits the device.
    pub fn supports(&self, d_in: usize, d_out: usize) -> bool {
        d_in <= self.max_input && d_out <= self.max_output
    }

    /// Seconds for `n` projections — frame-rate-bound, size-independent.
    pub fn seconds(&self, n_projections: usize) -> f64 {
        n_projections as f64 / self.frame_rate_hz
    }

    /// Projections per second (size-independent while it fits).
    pub fn throughput(&self, d_in: usize, d_out: usize) -> Option<f64> {
        self.supports(d_in, d_out).then_some(self.frame_rate_hz)
    }

    /// Joules for `n` projections.
    pub fn energy(&self, n_projections: usize) -> f64 {
        self.seconds(n_projections) * self.power_watts
    }

    /// Seconds one display/camera frame slot occupies — the scheduling
    /// quantum of the shard-aware projection service.
    pub fn slot_seconds(&self) -> f64 {
        1.0 / self.frame_rate_hz
    }

    /// Energy one occupied frame slot costs on one device.
    pub fn slot_energy(&self) -> f64 {
        self.slot_seconds() * self.power_watts
    }

    /// Energy attribution for a service schedule: per-shard occupied
    /// slot counts → joules (each shard is its own device; an idle
    /// shard's slots are free, so only *scheduled* slots are billed).
    pub fn service_energy(&self, slots_per_shard: &[u64]) -> f64 {
        slots_per_shard.iter().map(|&s| s as f64).sum::<f64>() * self.slot_energy()
    }

    /// Effective multiply-accumulates per second at a given size
    /// (the "parameters × rate" headline: 1e5 × 1e6 × 1.5e3 ≈ 1.5e14).
    pub fn effective_macs(&self, d_in: usize, d_out: usize) -> Option<f64> {
        self.throughput(d_in, d_out)
            .map(|r| r * d_in as f64 * d_out as f64)
    }

    /// A mode-sharded farm of `devices` such OPUs driven as one logical
    /// projector (the `ProjectorFarm` execution model): every device
    /// sees the same input frame and images its own slice of the output
    /// modes, so the frame rate is unchanged while output capacity —
    /// and therefore effective MAC/s — and power draw scale by N.
    pub fn farm(&self, devices: usize) -> OpuModel {
        assert!(devices >= 1);
        OpuModel {
            frame_rate_hz: self.frame_rate_hz,
            power_watts: self.power_watts * devices as f64,
            max_output: self.max_output * devices,
            max_input: self.max_input,
        }
    }
}

/// Roofline model of a GPU running the same projection digitally.
#[derive(Clone, Copy, Debug)]
pub struct GpuModel {
    pub peak_flops: f64,
    pub mem_bw: f64,
    pub power_watts: f64,
    pub mem_bytes: f64,
    pub launch_overhead_s: f64,
}

impl GpuModel {
    /// NVIDIA V100 SXM2 (2020 contemporary of the paper).
    pub fn v100() -> Self {
        GpuModel {
            peak_flops: 15.7e12,
            mem_bw: 900e9,
            power_watts: 300.0,
            mem_bytes: 32e9,
            launch_overhead_s: 10e-6,
        }
    }

    /// Whether the dense f32 matrix fits in device memory.
    pub fn supports(&self, d_in: usize, d_out: usize) -> bool {
        (d_in as f64) * (d_out as f64) * 4.0 <= self.mem_bytes
    }

    /// Seconds for ONE `d_out × d_in` mat-vec (a DFA feedback step for a
    /// single sample): roofline max of compute and bandwidth, plus
    /// launch.  Batching amortizes the matrix traffic — `batch` columns
    /// share one sweep of B.
    pub fn seconds(&self, d_in: usize, d_out: usize, batch: usize) -> f64 {
        let params = d_in as f64 * d_out as f64;
        let flops = 2.0 * params * batch as f64;
        let bytes = 4.0 * (params + (d_in + d_out) as f64 * batch as f64);
        let compute = flops / self.peak_flops;
        let memory = bytes / self.mem_bw;
        compute.max(memory) + self.launch_overhead_s
    }

    /// Projections per second at a batch size.
    pub fn throughput(&self, d_in: usize, d_out: usize, batch: usize) -> Option<f64> {
        self.supports(d_in, d_out)
            .then(|| batch as f64 / self.seconds(d_in, d_out, batch))
    }

    /// Joules for `n` projections at a batch size.
    pub fn energy(&self, d_in: usize, d_out: usize, batch: usize, n: usize) -> f64 {
        let secs = self.seconds(d_in, d_out, batch) * (n as f64 / batch as f64);
        secs * self.power_watts
    }
}

/// Host CPU model calibrated from a measured matmul benchmark.
#[derive(Clone, Copy, Debug)]
pub struct CpuModel {
    /// Measured sustained f32 MAC/s on the projection shape.
    pub macs_per_sec: f64,
    pub power_watts: f64,
}

impl CpuModel {
    pub fn measured(macs_per_sec: f64) -> Self {
        CpuModel {
            macs_per_sec,
            // Single desktop core package share, typical ~15 W.
            power_watts: 15.0,
        }
    }

    pub fn seconds(&self, d_in: usize, d_out: usize, batch: usize) -> f64 {
        (d_in as f64 * d_out as f64 * batch as f64) / self.macs_per_sec
    }

    pub fn throughput(&self, d_in: usize, d_out: usize) -> f64 {
        self.macs_per_sec / (d_in as f64 * d_out as f64)
    }

    /// Joules for `secs` of host compute at this model's package power.
    /// This is the energy attribution for streamed-medium tile
    /// generation (the per-tile clock a `StreamedMedium` charges is
    /// host *simulation* cost — the physical medium scatters for free;
    /// only the frame clock is device time).
    pub fn energy_for_secs(&self, secs: f64) -> f64 {
        secs * self.power_watts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn opu_matches_paper_numbers() {
        let opu = OpuModel::paper(Holography::OffAxis);
        // 1500 projections of size 1e5 per second (paper §III)
        assert_eq!(opu.throughput(1_000_000, 100_000), Some(1500.0));
        // ~20 mJ per projection at 30 W
        assert!((opu.energy(1) - 0.02).abs() < 1e-9);
        // "more than a hundred billion parameters"
        assert!(opu.effective_macs(1_000_000, 100_000).unwrap() > 1e14);
    }

    #[test]
    fn opu_rejects_oversize() {
        let opu = OpuModel::paper(Holography::OffAxis);
        assert!(opu.throughput(1_000_000, 200_000).is_none());
        let ps = OpuModel::paper(Holography::PhaseShifting);
        assert!(ps.throughput(1_000_000, 1_000_000).is_some());
        // phase-shifting trades frame rate for size
        assert!(ps.frame_rate_hz < 1500.0);
    }

    #[test]
    fn farm_scales_capacity_and_power_not_rate() {
        let one = OpuModel::paper(Holography::OffAxis);
        let four = one.farm(4);
        assert_eq!(four.frame_rate_hz, one.frame_rate_hz);
        assert_eq!(four.power_watts, 4.0 * one.power_watts);
        assert_eq!(four.max_output, 4 * one.max_output);
        // 4e5 output modes: out of reach for one device, in reach for 4.
        assert!(one.throughput(1_000_000, 400_000).is_none());
        assert_eq!(four.throughput(1_000_000, 400_000), Some(1500.0));
        // Effective MAC/s at full capacity scales by N.
        let m1 = one.effective_macs(1_000_000, one.max_output).unwrap();
        let m4 = four.effective_macs(1_000_000, four.max_output).unwrap();
        assert!((m4 / m1 - 4.0).abs() < 1e-9);
        // Energy per projection also scales by N (no free lunch).
        assert!((four.energy(1) - 4.0 * one.energy(1)).abs() < 1e-12);
    }

    #[test]
    fn slot_attribution_matches_projection_energy() {
        let opu = OpuModel::paper(Holography::OffAxis);
        // One slot = one frame = one projection on one device.
        assert!((opu.slot_seconds() - 1.0 / 1500.0).abs() < 1e-15);
        assert!((opu.slot_energy() - opu.energy(1)).abs() < 1e-12);
        // A 3-shard schedule: slots sum over shards, joules follow.
        let slots = [10u64, 7, 3];
        assert!((opu.service_energy(&slots) - opu.energy(20)).abs() < 1e-12);
        assert_eq!(opu.service_energy(&[]), 0.0);
    }

    #[test]
    fn cpu_gen_energy_attribution() {
        let cpu = CpuModel::measured(1e9);
        assert!((cpu.energy_for_secs(2.0) - 2.0 * cpu.power_watts).abs() < 1e-12);
        assert_eq!(cpu.energy_for_secs(0.0), 0.0);
        // Attribution is consistent with the seconds model: generating a
        // tile's worth of MACs costs its seconds × watts.
        let secs = cpu.seconds(100, 4096, 1);
        assert!((cpu.energy_for_secs(secs) - secs * 15.0).abs() < 1e-12);
    }

    #[test]
    fn gpu_small_is_overhead_bound_large_is_bw_bound() {
        let gpu = GpuModel::v100();
        // tiny projection: launch overhead dominates
        let t_small = gpu.seconds(10, 1024, 1);
        assert!(t_small < 2.0 * gpu.launch_overhead_s);
        // big projection: bandwidth term dominates
        let t_big = gpu.seconds(100_000, 100_000, 1);
        let bw_time = 4.0 * 1e10 / gpu.mem_bw;
        assert!((t_big - bw_time) / bw_time < 0.1);
    }

    #[test]
    fn gpu_batching_amortizes() {
        let gpu = GpuModel::v100();
        // 50k x 50k f32 = 10 GB: fits in 32 GB (1e5 x 1e5 would not).
        let t1 = gpu.throughput(50_000, 50_000, 1).unwrap();
        let t128 = gpu.throughput(50_000, 50_000, 128).unwrap();
        assert!(t128 > 20.0 * t1, "t1={t1} t128={t128}");
    }

    #[test]
    fn paper_efficiency_claim_holds_in_model() {
        // "up to one order of magnitude more power efficient" at large
        // scale, unbatched feedback (the DFA serving pattern).
        let opu = OpuModel::paper(Holography::OffAxis);
        let gpu = GpuModel::v100();
        let (d_in, d_out) = (1_000_000, 100_000);
        let opu_j = opu.energy(1000);
        let gpu_j = gpu.energy(d_in, d_out, 1, 1000);
        let ratio = gpu_j / opu_j;
        assert!(
            ratio > 5.0,
            "expected ≥5x efficiency edge, got {ratio:.1}"
        );
    }

    #[test]
    fn gpu_memory_gate() {
        let gpu = GpuModel::v100();
        // 1e6 x 1e5 f32 = 400 GB — does not fit; the OPU does not care.
        assert!(!gpu.supports(1_000_000, 100_000));
        assert!(OpuModel::paper(Holography::OffAxis)
            .supports(1_000_000, 100_000));
    }
}
