//! Simulated time accounting.
//!
//! The OPU's frame clock (1.5 kHz) is the pacing element of the hybrid
//! loop, but actually sleeping 667 µs per frame would make the 1-core
//! sandbox experiments dominated by idle time.  Instead every device
//! charges *simulated* time to a [`SimClock`]; experiments report both
//! wall-clock (what this host did) and simulated device time (what the
//! paper's hardware would take).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Monotonic simulated-time accumulator (nanoseconds).
#[derive(Clone, Default)]
pub struct SimClock {
    nanos: Arc<AtomicU64>,
}

impl SimClock {
    pub fn new() -> Self {
        Self::default()
    }

    /// Charge `seconds` of simulated time.
    ///
    /// The charge is *explicitly* saturated: negative inputs charge 0 ns
    /// (not a silent debug-only assertion that compiles out in release
    /// and then truncates through `as u64`), and charges past `u64` nanos
    /// pin at `u64::MAX` instead of wrapping.  Non-finite input is
    /// rejected loudly — a NaN charge would otherwise poison every
    /// downstream consumer of this clock (the adaptive scheduler derives
    /// shard weights from slot clocks, so a single bad charge must not
    /// be able to skew the whole schedule silently).
    pub fn advance_secs(&self, seconds: f64) {
        assert!(
            seconds.is_finite(),
            "non-finite sim-clock charge: {seconds} s"
        );
        let ns = (seconds * 1e9).round().clamp(0.0, u64::MAX as f64) as u64;
        self.nanos.fetch_add(ns, Ordering::Relaxed);
    }

    /// Charge `slots` display/camera frame slots at `frame_rate_hz` —
    /// per-slot timing attribution for the shard-aware projection
    /// service (each scheduled slot occupies one frame period on its
    /// shard's clock, whether or not the frame was full).
    pub fn advance_slots(&self, slots: u64, frame_rate_hz: f64) {
        assert!(
            frame_rate_hz.is_finite() && frame_rate_hz > 0.0,
            "frame rate must be positive and finite: {frame_rate_hz} Hz"
        );
        self.advance_secs(slots as f64 / frame_rate_hz);
    }

    pub fn now_secs(&self) -> f64 {
        self.nanos.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn reset(&self) {
        self.nanos.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let c = SimClock::new();
        c.advance_secs(0.5);
        c.advance_secs(0.25);
        assert!((c.now_secs() - 0.75).abs() < 1e-9);
    }

    #[test]
    fn slots_charge_frame_periods() {
        let c = SimClock::new();
        c.advance_slots(3, 1500.0);
        assert!((c.now_secs() - 3.0 / 1500.0).abs() < 1e-12);
        c.advance_slots(0, 1500.0);
        assert!((c.now_secs() - 3.0 / 1500.0).abs() < 1e-12);
    }

    #[test]
    fn negative_charge_is_saturated_to_zero() {
        // Release builds used to rely on `as u64` truncation semantics
        // here; the clamp makes "never rewind the clock" explicit.
        let c = SimClock::new();
        c.advance_secs(0.25);
        c.advance_secs(-5.0);
        assert!((c.now_secs() - 0.25).abs() < 1e-12);
        c.advance_secs(-0.0);
        assert!((c.now_secs() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn huge_charge_saturates_instead_of_wrapping() {
        let c = SimClock::new();
        // 1e300 s * 1e9 ns/s is far beyond u64: the charge must pin at
        // u64::MAX nanos (~584 years of sim time), not wrap to garbage.
        c.advance_secs(1e300);
        let max_secs = u64::MAX as f64 / 1e9;
        assert!((c.now_secs() - max_secs).abs() < 1.0, "{}", c.now_secs());
    }

    #[test]
    #[should_panic(expected = "non-finite sim-clock charge")]
    fn nan_charge_is_rejected() {
        SimClock::new().advance_secs(f64::NAN);
    }

    #[test]
    #[should_panic(expected = "non-finite sim-clock charge")]
    fn infinite_charge_is_rejected() {
        SimClock::new().advance_secs(f64::INFINITY);
    }

    #[test]
    #[should_panic(expected = "frame rate must be positive")]
    fn zero_frame_rate_is_rejected() {
        SimClock::new().advance_slots(1, 0.0);
    }

    #[test]
    fn clones_share() {
        let c = SimClock::new();
        let c2 = c.clone();
        c2.advance_secs(1.0);
        assert!((c.now_secs() - 1.0).abs() < 1e-9);
        c.reset();
        assert_eq!(c2.now_secs(), 0.0);
    }
}
