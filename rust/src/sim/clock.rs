//! Simulated time accounting.
//!
//! The OPU's frame clock (1.5 kHz) is the pacing element of the hybrid
//! loop, but actually sleeping 667 µs per frame would make the 1-core
//! sandbox experiments dominated by idle time.  Instead every device
//! charges *simulated* time to a [`SimClock`]; experiments report both
//! wall-clock (what this host did) and simulated device time (what the
//! paper's hardware would take).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Monotonic simulated-time accumulator (nanoseconds).
#[derive(Clone, Default)]
pub struct SimClock {
    nanos: Arc<AtomicU64>,
}

impl SimClock {
    pub fn new() -> Self {
        Self::default()
    }

    /// Charge `seconds` of simulated time.
    pub fn advance_secs(&self, seconds: f64) {
        debug_assert!(seconds >= 0.0);
        let ns = (seconds * 1e9).round() as u64;
        self.nanos.fetch_add(ns, Ordering::Relaxed);
    }

    /// Charge `slots` display/camera frame slots at `frame_rate_hz` —
    /// per-slot timing attribution for the shard-aware projection
    /// service (each scheduled slot occupies one frame period on its
    /// shard's clock, whether or not the frame was full).
    pub fn advance_slots(&self, slots: u64, frame_rate_hz: f64) {
        debug_assert!(frame_rate_hz > 0.0);
        self.advance_secs(slots as f64 / frame_rate_hz);
    }

    pub fn now_secs(&self) -> f64 {
        self.nanos.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn reset(&self) {
        self.nanos.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let c = SimClock::new();
        c.advance_secs(0.5);
        c.advance_secs(0.25);
        assert!((c.now_secs() - 0.75).abs() < 1e-9);
    }

    #[test]
    fn slots_charge_frame_periods() {
        let c = SimClock::new();
        c.advance_slots(3, 1500.0);
        assert!((c.now_secs() - 3.0 / 1500.0).abs() < 1e-12);
        c.advance_slots(0, 1500.0);
        assert!((c.now_secs() - 3.0 / 1500.0).abs() < 1e-12);
    }

    #[test]
    fn clones_share() {
        let c = SimClock::new();
        let c2 = c.clone();
        c2.advance_secs(1.0);
        assert!((c.now_secs() - 1.0).abs() < 1e-9);
        c.reset();
        assert_eq!(c2.now_secs(), 0.0);
    }
}
