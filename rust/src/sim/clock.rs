//! Simulated time accounting.
//!
//! The OPU's frame clock (1.5 kHz) is the pacing element of the hybrid
//! loop, but actually sleeping 667 µs per frame would make the 1-core
//! sandbox experiments dominated by idle time.  Instead every device
//! charges *simulated* time to a [`SimClock`]; experiments report both
//! wall-clock (what this host did) and simulated device time (what the
//! paper's hardware would take).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Monotonic simulated-time accumulator (nanoseconds).
#[derive(Clone, Default)]
pub struct SimClock {
    nanos: Arc<AtomicU64>,
}

impl SimClock {
    pub fn new() -> Self {
        Self::default()
    }

    /// Charge `seconds` of simulated time.
    pub fn advance_secs(&self, seconds: f64) {
        debug_assert!(seconds >= 0.0);
        let ns = (seconds * 1e9).round() as u64;
        self.nanos.fetch_add(ns, Ordering::Relaxed);
    }

    pub fn now_secs(&self) -> f64 {
        self.nanos.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn reset(&self) {
        self.nanos.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let c = SimClock::new();
        c.advance_secs(0.5);
        c.advance_secs(0.25);
        assert!((c.now_secs() - 0.75).abs() < 1e-9);
    }

    #[test]
    fn clones_share() {
        let c = SimClock::new();
        let c2 = c.clone();
        c2.advance_secs(1.0);
        assert!((c.now_secs() - 1.0).abs() < 1e-9);
        c.reset();
        assert_eq!(c2.now_secs(), 0.0);
    }
}
