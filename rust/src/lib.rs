//! # litl — Light-in-the-loop
//!
//! A production-grade reproduction of *"Light-in-the-loop: using a photonics
//! co-processor for scalable training of neural networks"* (Launay et al.,
//! LightOn, 2020).
//!
//! The paper demonstrates the first photonic co-processor used to accelerate
//! the *training* (not inference) of digitally-implemented neural networks:
//! the forward pass runs on silicon, while the error-feedback path of Direct
//! Feedback Alignment (DFA) — a fixed random projection of the output error —
//! is computed optically by LightOn's Optical Processing Unit (OPU) using
//! multiple light scattering and off-axis holography.
//!
//! This crate is the **Layer-3 rust coordinator** of a three-layer stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): the compute
//!   hot-spots (tiled random projection, fused DFA+Adam update, ternary
//!   quantization, holography demodulation), validated against pure-jnp
//!   oracles.
//! * **L2** — JAX model (`python/compile/model.py`): MLP forward/backward,
//!   DFA and BP training steps, and the optical physics twin, AOT-lowered
//!   once to HLO text artifacts by `python/compile/aot.py`.
//! * **L3** — this crate: loads the HLO artifacts via PJRT (`runtime`),
//!   owns the training loop and the OPU device (`coordinator`, `optics`),
//!   and never touches python at run time.
//!
//! Because no physical OPU (nor its proprietary driver) is available, the
//! optical hardware is replaced by a physics-faithful simulator
//! ([`optics`]): complex Gaussian transmission matrix, SLM ternary encoding,
//! speckle intensity formation, off-axis holography demodulation, camera
//! shot/read noise and ADC quantization, and a frame-clock/power timing
//! model calibrated to the paper's figures (1.5 kHz frames, ~1e5 maximum
//! dimension, ~30 W).
//!
//! ## The projector farm (sharded multi-device execution)
//!
//! The paper's headline is *scalability* — projections at dimensions
//! where digital hardware stalls — and the follow-up work drives the
//! same DFA error-projection step across multiple devices.  This crate's
//! execution model for that is the
//! [`coordinator::farm::ProjectorFarm`]: one logical projector made of N
//! virtual devices, each owning a contiguous **mode range** of the same
//! transmission matrix ([`optics::medium::TransmissionMatrix::split_modes`]),
//! its own camera-noise PCG *stream*, simulated clock and energy
//! account.  A batch `[B, d_in]` fans out to every shard concurrently on
//! the [`exec::ThreadPool`]'s scoped submit/join API and the per-shard
//! quadratures are concatenated in shard order, so results are
//! deterministic for a given seed regardless of scheduling.
//!
//! **Parity guarantee:** at `shards = 1` the farm is *bit-identical* to
//! the pre-farm single-device path (same medium, same RNG stream; the
//! gather is a pure copy), and at any shard count it equals a single device over the
//! equivalent stacked medium — exactly for the digital comparator,
//! to fp/ADC tolerance for noiseless optics (property-tested in
//! `rust/tests/farm_parity.rs`).  The digital baseline stays honest at
//! multi-core scale through row-block-parallel matmuls
//! ([`tensor::matmul_pooled`] and friends) that are bitwise identical to
//! their serial forms.  `--shards N` on the CLI (or `shards = N` in a
//! config file) routes training through the farm; `benches/e4_scaling.rs`
//! sweeps the shard count and reports throughput and speedup.
//!
//! ## The shard-aware projection service (frame-slot scheduling)
//!
//! The farm parallelizes *inside* one device batch; the serving layer on
//! top is [`coordinator::service::ShardedProjectionService`]: a
//! frame-slot scheduler that assigns concurrent client submissions to
//! concrete **(shard, frame-slot)** pairs.  Each shard device owns a
//! bounded MPMC lane ([`exec::queue::Lanes`]) and a dedicated worker
//! thread; the single-threaded scheduler coalesces small requests into
//! shared frame sequences and carves them along a
//! [`config::Partition`] axis — `modes` (every shard images its mode
//! slice of every frame) or `batch` (full-medium replicas each take a
//! contiguous row range; the small-mode/large-batch regime).  Scheduled
//! slots are attributed per shard to simulated clocks and the
//! [`sim::power::OpuModel`] slot-energy model.
//!
//! **Determinism contract:** for a fixed submission order the schedule —
//! packing, (shard, slot) assignment, each shard's job sequence and
//! hence its noise draws — is deterministic; `shards = 1` is bitwise the
//! device-agnostic [`coordinator::service::ProjectionService`] path, and
//! digital shards are bitwise the single-device reference at any shard
//! count under either partition (`rust/tests/service_schedule.rs`).
//! `--partition modes|batch` selects the axis on the CLI;
//! `benches/e4_scaling.rs` (E4.4) sweeps clients × shards × partition.
//!
//! ## The unified `Topology` builder (declarative device graphs)
//!
//! Every projection deployment shape — single device, homogeneous farm,
//! heterogeneous fleet, weighted schedule, either partition, either
//! medium backing, owned or shared pool — is one declarative
//! [`coordinator::topology::Topology`] value: a validated list of shard
//! specs (device kind, service **weight**, optional explicit mode range
//! and noise stream) plus the partition/backing/pool policies.  One
//! build path (`build_devices` / `build_farm` / `build_projector` /
//! `build_service`) replaces the farm's legacy constructor matrix,
//! which survives only as `#[deprecated]` shims.  `--topology
//! hetero:opt:4+dig:2`-style shorthand (and a `[topology]` TOML
//! section) selects it from the CLI; the descriptor is hashable
//! ([`coordinator::topology::Topology::stable_hash`]) and serializable
//! (`shorthand()` round-trips through `parse()`).
//!
//! **Parity guarantee:** equal-weight homogeneous topologies are
//! *bitwise identical* to the legacy constructions (same
//! [`util::balanced_widths`] windows — [`util::weighted_widths`]
//! reduces to it exactly for equal weights — same noise-stream
//! assignment, same schedules), pinned in `rust/tests/topology.rs`.
//! Unequal weights make the farm and the frame-slot scheduler split
//! batch rows **proportionally to shard service rates** — the ROADMAP's
//! weighted frame-slot scheduling — and mixed optical/digital specs
//! give heterogeneous fleets; `benches/e4_scaling.rs` (E4.5) measures
//! weighted-vs-even wall time on skewed device speeds.
//!
//! ## The streamed projection engine (memory-less media at 1e5+ modes)
//!
//! The medium is *defined by its seed*, not by a stored buffer: row `r`,
//! column `c` of the transmission matrix is Box–Muller pair `c` of the
//! dedicated PCG stream for row `r`, reachable in O(log c) via
//! [`util::rng::Pcg64::advance`] (counter-addressable generation — see
//! [`optics::medium`]).  Two backings realize the same definition,
//! selected by `--medium materialized|streamed`
//! ([`config::MediumBacking`]): dense tensors, or
//! [`optics::stream::StreamedMedium`] — a tiled projection engine that
//! regenerates row-tiles into reusable scratch, fuses the quadrature
//! accumulation into the tile walk (batch-aware, parallel over the
//! thread pool's scoped submit/join), and never holds a `[d_in, modes]`
//! slice: resident TM bytes are `O(tile)` instead of `O(d_in × modes)`.
//!
//! **Parity guarantee:** the streamed path is **bitwise equal** to the
//! materialized path for any seed/shape — digital, noiseless *and*
//! noisy optics (identical field at the camera → identical noise draws)
//! — and streamed shards compose with the farm and the shard-aware
//! service under both partitions with the same bit parity
//! (`rust/tests/stream_parity.rs`).  `benches/e6_streaming.rs` sweeps
//! modes 1e4 → 1e6 and reports throughput plus the peak-RSS proxy
//! (bytes resident vs bytes the dense slice would need); the CI
//! `stream-smoke` job replays it at 1e5 modes under a hard `ulimit -v`
//! where the dense allocation provably fails — the memory-less
//! guarantee is enforced, not just documented.
//!
//! ## The fast generation path (batched kernel + cross-step tile cache)
//!
//! Generation itself is engineered on two axes.  (1) The Box–Muller
//! walk runs through a **batched lane kernel**
//! ([`util::rng::Pcg64::fill_normal`] /
//! [`util::rng::Pcg64::fill_normal_quadrature`]): uniforms land in
//! [`util::rng::NORMAL_LANE`]-pair stack arrays and each
//! transcendental runs as its own tight loop, **bitwise identical** to
//! the scalar walk (kept as `fill_normal_scalar`, the pinned oracle) —
//! including spare carry, odd lengths and `advance`-seeked offsets —
//! with the CI `gen-kernel-bench` job failing any speed regression.
//! The transcendentals themselves are **crate-owned polynomial
//! kernels** ([`util::mathk`]): branch-free `ln`/`sin_cos` with no
//! per-element libm calls left in the hot loop, shared by the scalar
//! oracle and the lane kernel (so scalar==lane parity holds by
//! construction) and built from `+ − × ÷ sqrt` only, which makes the
//! TM bits *platform-independent* — the same seed generates the same
//! medium on any IEEE-754 host, regardless of its libm (design
//! pre-validated in `python/compile/kernels/boxmuller.py`).
//! (2) Repeated training steps stop regenerating identical tiles: the
//! streamed backing takes a **bounded tile cache**
//! ([`optics::stream::TileCache`], `--tile-cache-mb`, default off)
//! shared across pool jobs and shard windows; cached and uncached
//! projections are bitwise equal, hits charge zero generation
//! sim-seconds, and the byte budget folds into
//! `resident_tm_bytes` so the `stream-smoke` ceiling proof covers it.
//! The cache is **lock-striped** (`--tile-cache-stripes`, default auto
//! = next power of two ≥ the pool's threads) with per-stripe CLOCK
//! recency, so a pool's worth of concurrent hits takes one short
//! stripe lock each instead of serializing on a global mutex; stripes
//! change contention and residency layout only, never bits (striped ==
//! single-stripe, pinned in `stream_parity.rs`), and the CI
//! `gen-kernel-bench` job gates per-thread hit throughput via the E6.4
//! contention sweep.
//!
//! ## The serving control plane (adaptive weights, failover, admission)
//!
//! Static topology weights describe a fleet at deploy time; production
//! fleets drift — thermal throttling, noisy neighbours, a dead OPU.
//! The service carries a self-correcting control plane of three
//! independent, individually-gated policies
//! ([`coordinator::service::ShardServiceConfig`]):
//!
//! * **Adaptive weights** ([`coordinator::service::AdaptConfig`],
//!   `--adapt-weights`): each shard worker publishes a windowed EWMA of
//!   its observed rows/s (`service_shard{i}_rate_ewma`; the occupancy
//!   `util` gauge is likewise a windowed EWMA, not a lifetime
//!   cumulative), and every re-plan interval the scheduler re-derives
//!   the [`util::weighted_widths`] split from those rates — with a
//!   hysteresis band so measurement jitter does not thrash the plan
//!   (`service_replans`, `service_shard{i}_eff_weight`).
//! * **Failover** ([`coordinator::service::FailoverConfig`],
//!   `--failover`): a per-shard health state machine — healthy, tripped
//!   by an error streak or a stall timeout, probation on re-admission —
//!   force-fails a tripped shard's in-flight slots and **drains its
//!   lane onto survivors**.  Batch-partition shards are replicas, so
//!   drained frames re-route trivially; modes-partition shards need
//!   medium re-windowing, supplied by an optional rebuild factory
//!   ([`coordinator::service::ShardRebuild`], wired automatically by
//!   `Topology::build_service`), and fail fast otherwise
//!   (`service_failovers`, `service_shard{i}_state`).
//! * **Admission control** ([`coordinator::service::AdmissionConfig`],
//!   `--admit-rate-fps`): per-client token buckets with a bounded
//!   backpressure wait, so one hot client saturates its own budget
//!   instead of the queue (`service_admission_throttled`), plus
//!   `service_latency_p{50,95,99}` submit→reply SLO percentiles.
//!
//! **Determinism contract:** every knob defaults *off*, and off means
//! bitwise-off — the scheduler runs the exact pre-control-plane
//! schedule, pinned by `tests/{service_schedule,topology,
//! stream_parity}.rs`.  With the plane on, the shutdown path guarantees
//! no client ever hangs: in-flight and queued frames receive errors,
//! never silence (`tests/service_control.rs`), and the whole story is
//! load-proven by `benches/e7_loadgen.rs` — hundreds of concurrent
//! clients, a mid-run shard kill, zero hangs, degraded throughput
//! gated against the healthy baseline in the CI `loadgen-smoke` job
//! (`E7_DEGRADED_MIN_FRAC`).
//!
//! ## Observability (frame-level tracing & telemetry export)
//!
//! Every frame's life — admit, queue wait, schedule, lane wait,
//! per-shard project, gather — and every trainer step's phase split
//! (forward vs optical projection vs DFA+Adam apply vs data load) is
//! traceable end to end.  [`metrics::trace`] is the substrate: a
//! process-global session ([`metrics::trace::TraceSession`]) over
//! bounded per-thread span rings, gated by one atomic load so `--trace
//! off` (the default) costs a few relaxed atomics and keeps pinned
//! schedules bitwise-unchanged.  `--trace summary` turns on cheap
//! profiling histograms (`stream_gen_ns` / `stream_cache_hit_ns` tile
//! generation vs cache-hit latency in [`optics::stream`]) and periodic
//! per-stage p50/p95/p99 summary lines from the trainer; `--trace
//! full` additionally records span events, drained at session end into
//! a [`metrics::trace::TraceReport`] with per-frame stage breakdowns
//! ([`metrics::trace::FrameBreakdown`]) whose critical-path stage sum
//! never exceeds the frame's end-to-end latency.  Spans survive
//! failover re-routes (lane-wait hand-off between shards) and ring
//! overflow degrades to counted drops, never corruption.
//!
//! ## Networked projector servers (the fleet of boxes)
//!
//! The paper's co-processor is a separate physical device behind a
//! link; [`net`] makes the repo's shards separable the same way.  The
//! service's submission protocol is promoted into a versioned wire
//! format ([`net::frame`]: length-prefixed binary frames — magic,
//! version, CRC32, request/response/error/health opcodes — over TCP or
//! Unix domain sockets, untrusted lengths capped and `try_reserve`d),
//! `litl serve` hosts shards of a `Topology` behind a listener
//! ([`net::ProjectorServer`]), and [`net::RemoteProjector`] stands in
//! for them behind the same [`coordinator::projector::Projector`]
//! surface the trainer and the sharded service already consume —
//! declared per shard via `remote:<addr>` topology endpoints
//! (`opt:2!tcp:host:9000` shorthand), so one descriptor builds a mixed
//! local+remote fleet.  Reconnects use bounded exponential backoff and
//! happen only *between* requests; with session resume off, an
//! in-flight frame on a dead connection completes with an error, so
//! the failover state machine trips naturally on a killed server.
//! With `--net-resume on`, a redialed client re-attaches its stream
//! (`resume`/`resume_ok` cursor negotiation) and re-requests the
//! in-flight frame, which the server's bounded per-session replay
//! journal executes **exactly once** — transport death costs retries,
//! never bits, and never a double noise draw.  Both ends also take a
//! seeded [`net::FaultPlanCfg`] (`--fault-plan`) for deterministic
//! chaos drills: connection cuts, partial writes, bit corruption,
//! stalls, and device error bursts, reproducible from one seed and
//! zero-cost when absent.  Warm-start persistence rides along: hot
//! [`optics::stream::TileCache`] tiles snapshot to disk
//! (`--tile-cache-save`/`--tile-cache-load`) and training resumes from
//! checkpoints (`--resume`) through [`coordinator::checkpoint`];
//! `litl serve` drains in-flight work and flushes its snapshot on
//! SIGTERM, and reclaims stale UDS socket files safely at bind.
//!
//! **Parity guarantee:** a loopback remote shard — TCP or UDS — is
//! **bitwise identical** to the same shard in-process, noisy optics
//! and streamed+cached media included: tensors travel as raw IEEE-754
//! bits, each shard's requests serialize on its own device (noise-draw
//! order = submission order), and in-flight requests are never
//! *blindly* retried — resume re-requests only the exact in-flight
//! frame, deduplicated by the journal.  Pinned in
//! `rust/tests/net_parity.rs`; `rust/tests/chaos.rs` (CI
//! `chaos-smoke`) extends the pin through seeded fault injection —
//! faulted runs with resume on finish bitwise identical to fault-free
//! at shards 1/2/4 × both partitions; the CI `net-smoke` job proves
//! parity across real process boundaries and kills a server mid-run to
//! prove failover drains onto survivors with zero client hangs.
//! `docs/operator-guide.md` and `docs/cutover-rehearsal-checklist.md`
//! cover running the fleet and the chaos drill.
//!
//! [`metrics::export`] turns the same data into standard formats:
//! Chrome `trace_event` JSON (`--trace-out trace.json`, loadable in
//! Perfetto / `chrome://tracing`, one timeline row per pipeline
//! thread) and Prometheus text exposition of the full
//! [`metrics::Registry`] — counters, gauges, and histograms rendered
//! as monotone cumulative `_bucket{le=...}` series — on `--metrics-out
//! FILE` at exit.  Both emitters are pure functions over the report /
//! registry, so tests and the CI `trace-smoke` job validate the bytes
//! (jq-parsed Chrome JSON, collision-free Prometheus names) without a
//! browser in the loop; `rust/tests/trace_spans.rs` pins span balance,
//! the breakdown-vs-latency bound, overflow behaviour, and that
//! tracing on vs off leaves pinned schedules bitwise identical.
#![allow(clippy::needless_range_loop)]

pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod exec;
pub mod metrics;
pub mod net;
pub mod optics;
pub mod runtime;
pub mod sim;
pub mod tensor;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
