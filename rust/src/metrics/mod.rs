//! Metrics registry: counters, gauges, histograms + CSV/JSONL emitters.
//!
//! The coordinator records every training step (loss, step time, frame
//! count, energy) and the projection service records device-level stats
//! (frames, queue depth, batch occupancy).  Everything is cheap,
//! lock-per-metric, and exportable:
//!
//! * `snapshot()` → flat name→value map (logged / asserted in tests)
//! * [`CsvWriter`] → one row per step for loss curves (EXPERIMENTS.md)
//! * JSONL via `crate::util::json` for experiment records.
//! * [`trace`] → frame-level span tracing (begin/end per pipeline stage)
//! * [`export`] → Chrome `trace_event` JSON and Prometheus text dumps

pub mod export;
pub mod trace;

use std::collections::BTreeMap;
use std::io::Write;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};

use crate::util::stats::Welford;

/// Monotonic counter.
#[derive(Clone, Default)]
pub struct Counter {
    value: Arc<AtomicU64>,
}

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Last-value gauge (f64 bits in an atomic).
#[derive(Clone, Default)]
pub struct Gauge {
    bits: Arc<AtomicU64>,
}

impl Gauge {
    pub fn set(&self, x: f64) {
        self.bits.store(x.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// Streaming distribution (Welford + reservoir-less percentd via ring).
#[derive(Clone, Default)]
pub struct Histogram {
    inner: Arc<Mutex<HistInner>>,
}

#[derive(Default)]
struct HistInner {
    welford: Welford,
    // Keep the most recent window for percentiles.
    ring: Vec<f64>,
    pos: usize,
}

const RING: usize = 4096;

impl Histogram {
    /// Poison-tolerant lock: the inner state is a plain accumulator (a
    /// panic mid-`observe` cannot break any invariant worse than one
    /// lost sample), and a metrics mutex poisoned by one dying thread
    /// must never crash every other thread that reports through it.
    fn lock(&self) -> MutexGuard<'_, HistInner> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    pub fn observe(&self, x: f64) {
        let mut h = self.lock();
        h.welford.push(x);
        if h.ring.len() < RING {
            h.ring.push(x);
        } else {
            let p = h.pos;
            h.ring[p] = x;
            h.pos = (h.pos + 1) % RING;
        }
    }

    pub fn count(&self) -> u64 {
        self.lock().welford.count()
    }

    pub fn mean(&self) -> f64 {
        self.lock().welford.mean()
    }

    pub fn std(&self) -> f64 {
        self.lock().welford.std()
    }

    pub fn min(&self) -> f64 {
        self.lock().welford.min()
    }

    pub fn max(&self) -> f64 {
        self.lock().welford.max()
    }

    /// Percentile over the recent window.
    ///
    /// An empty histogram reports `0.0` — a well-defined, NaN-free
    /// value.  The previous `f64::NAN` poisoned every downstream
    /// consumer that compared or exported the number (NaN fails all
    /// comparisons silently and is not valid Prometheus output).
    pub fn percentile(&self, q: f64) -> f64 {
        let h = self.lock();
        if h.ring.is_empty() {
            return 0.0;
        }
        crate::util::stats::percentile(&h.ring, q)
    }

    /// Sum of all observed values (`mean * count`; exact enough for
    /// exposition — Welford tracks the mean in f64).
    pub fn sum(&self) -> f64 {
        let h = self.lock();
        h.welford.mean() * h.welford.count() as f64
    }

    /// Copy of the recent-window samples (insertion order, unsorted).
    pub fn window(&self) -> Vec<f64> {
        self.lock().ring.clone()
    }

    /// Clear all state — count, moments and the percentile window —
    /// so the histogram starts a fresh window.  Used by the periodic
    /// telemetry summary to report per-window (not lifetime)
    /// percentiles.
    pub fn reset(&self) {
        let mut h = self.lock();
        h.welford = Welford::default();
        h.ring.clear();
        h.pos = 0;
    }
}

/// Named metrics registry shared across coordinator components.
#[derive(Clone, Default)]
pub struct Registry {
    inner: Arc<Mutex<RegistryInner>>,
}

#[derive(Default)]
struct RegistryInner {
    counters: BTreeMap<String, Counter>,
    gauges: BTreeMap<String, Gauge>,
    histograms: BTreeMap<String, Histogram>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Poison-tolerant lock (same reasoning as [`Histogram`]'s: plain
    /// maps of handles, shared by every component in the process).
    fn lock(&self) -> MutexGuard<'_, RegistryInner> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    pub fn counter(&self, name: &str) -> Counter {
        let mut inner = self.lock();
        inner.counters.entry(name.to_string()).or_default().clone()
    }

    pub fn gauge(&self, name: &str) -> Gauge {
        let mut inner = self.lock();
        inner.gauges.entry(name.to_string()).or_default().clone()
    }

    pub fn histogram(&self, name: &str) -> Histogram {
        let mut inner = self.lock();
        inner.histograms.entry(name.to_string()).or_default().clone()
    }

    /// Sum of all counters whose name starts with `prefix` and ends with
    /// `suffix` — rolls per-shard counters (`service_shard3_frames`,
    /// `service_shard3_slots`, …) up to a fleet total without the caller
    /// knowing the shard count.  Prefix and suffix must cover disjoint
    /// spans of the name (a name shorter than their combined length
    /// never matches), so `("service_shard", "_shard")` cannot
    /// double-count an overlap.
    pub fn sum_counters(&self, prefix: &str, suffix: &str) -> f64 {
        let inner = self.lock();
        inner
            .counters
            .iter()
            .filter(|(name, _)| name_matches(name, prefix, suffix))
            .map(|(_, c)| c.get() as f64)
            .sum()
    }

    /// [`Registry::sum_counters`] for gauges: the fleet view of the
    /// per-shard gauges (`service_shard3_slot_s`, `…_util`, …).  Summing
    /// is the right roll-up for additive gauges like slot-seconds and
    /// lane depth; divide by the shard count for intensive ones like
    /// utilization.
    pub fn sum_gauges(&self, prefix: &str, suffix: &str) -> f64 {
        let inner = self.lock();
        inner
            .gauges
            .iter()
            .filter(|(name, _)| name_matches(name, prefix, suffix))
            .map(|(_, g)| g.get())
            .sum()
    }

    /// Every registered counter, sorted by name (handles share state
    /// with the registry — reading them later sees live values).  The
    /// enumeration views exist for exporters ([`export::prometheus_text`]
    /// dumps the full registry) without exposing the inner maps.
    pub fn counters(&self) -> Vec<(String, Counter)> {
        let inner = self.lock();
        inner
            .counters
            .iter()
            .map(|(n, c)| (n.clone(), c.clone()))
            .collect()
    }

    /// Every registered gauge, sorted by name.
    pub fn gauges(&self) -> Vec<(String, Gauge)> {
        let inner = self.lock();
        inner
            .gauges
            .iter()
            .map(|(n, g)| (n.clone(), g.clone()))
            .collect()
    }

    /// Every registered histogram, sorted by name.
    pub fn histograms(&self) -> Vec<(String, Histogram)> {
        let inner = self.lock();
        inner
            .histograms
            .iter()
            .map(|(n, h)| (n.clone(), h.clone()))
            .collect()
    }

    /// Flat snapshot of every metric (histograms expand to _mean/_p50/...).
    pub fn snapshot(&self) -> BTreeMap<String, f64> {
        let inner = self.lock();
        let mut out = BTreeMap::new();
        for (name, c) in &inner.counters {
            out.insert(name.clone(), c.get() as f64);
        }
        for (name, g) in &inner.gauges {
            out.insert(name.clone(), g.get());
        }
        for (name, h) in &inner.histograms {
            if h.count() == 0 {
                continue;
            }
            out.insert(format!("{name}_count"), h.count() as f64);
            out.insert(format!("{name}_mean"), h.mean());
            out.insert(format!("{name}_p50"), h.percentile(50.0));
            out.insert(format!("{name}_p95"), h.percentile(95.0));
            out.insert(format!("{name}_p99"), h.percentile(99.0));
            out.insert(format!("{name}_max"), h.max());
        }
        out
    }
}

/// Prefix/suffix roll-up predicate shared by the counter and gauge
/// roll-ups: both ends must match over disjoint spans of the name.
fn name_matches(name: &str, prefix: &str, suffix: &str) -> bool {
    name.len() >= prefix.len() + suffix.len()
        && name.starts_with(prefix)
        && name.ends_with(suffix)
}

/// Line-buffered CSV writer with a fixed header.
pub struct CsvWriter {
    file: std::io::BufWriter<std::fs::File>,
    columns: Vec<String>,
}

impl CsvWriter {
    pub fn create(path: &str, columns: &[&str]) -> crate::Result<Self> {
        if let Some(parent) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut file = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(file, "{}", columns.join(","))?;
        Ok(CsvWriter {
            file,
            columns: columns.iter().map(|s| s.to_string()).collect(),
        })
    }

    pub fn row(&mut self, values: &[f64]) -> crate::Result<()> {
        assert_eq!(values.len(), self.columns.len());
        let line: Vec<String> = values.iter().map(|v| format!("{v}")).collect();
        writeln!(self.file, "{}", line.join(","))?;
        Ok(())
    }

    pub fn flush(&mut self) -> crate::Result<()> {
        self.file.flush()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges() {
        let reg = Registry::new();
        reg.counter("frames").add(3);
        reg.counter("frames").inc();
        reg.gauge("loss").set(0.5);
        let snap = reg.snapshot();
        assert_eq!(snap["frames"], 4.0);
        assert_eq!(snap["loss"], 0.5);
    }

    #[test]
    fn sum_counters_rolls_up_per_shard_names() {
        let reg = Registry::new();
        reg.counter("service_shard0_frames").add(3);
        reg.counter("service_shard1_frames").add(5);
        reg.counter("service_shard1_slots").add(9);
        reg.counter("other").add(100);
        assert_eq!(reg.sum_counters("service_shard", "_frames"), 8.0);
        assert_eq!(reg.sum_counters("service_shard", "_slots"), 9.0);
        assert_eq!(reg.sum_counters("service_shard", "_none"), 0.0);
    }

    #[test]
    fn sum_counters_edges() {
        let reg = Registry::new();
        reg.counter("shard0_x").add(2);
        reg.counter("shard1_x").add(3);
        // Empty prefix/suffix are wildcards on that end.
        assert_eq!(reg.sum_counters("", "_x"), 5.0);
        assert_eq!(reg.sum_counters("shard", ""), 5.0);
        assert_eq!(reg.sum_counters("", ""), 5.0);
        // Exact-name match: prefix == name, suffix empty (and vice versa).
        assert_eq!(reg.sum_counters("shard0_x", ""), 2.0);
        // Prefix and suffix may not overlap inside one name: "_x" as both
        // would need the name to contain it twice.
        reg.counter("_x").add(100);
        assert_eq!(reg.sum_counters("_x", "_x"), 0.0);
        // A zero-valued counter contributes zero, not a missing entry.
        reg.counter("shard2_x");
        assert_eq!(reg.sum_counters("shard", "_x"), 5.0);
    }

    #[test]
    fn sum_gauges_rolls_up_per_shard_names() {
        let reg = Registry::new();
        reg.gauge("service_shard0_slot_s").set(0.25);
        reg.gauge("service_shard1_slot_s").set(0.5);
        reg.gauge("service_shard1_util").set(0.9);
        reg.gauge("service_queue_depth").set(7.0);
        reg.counter("service_shard0_slot_s_ctr").add(99); // counters don't leak in
        assert_eq!(reg.sum_gauges("service_shard", "_slot_s"), 0.75);
        assert_eq!(reg.sum_gauges("service_shard", "_util"), 0.9);
        assert_eq!(reg.sum_gauges("service_shard", "_missing"), 0.0);
    }

    #[test]
    fn stripe_gauge_roll_up_excludes_the_total_gauge() {
        // The streamed tile cache publishes one gauge per stripe
        // (`stream_cache_stripe<i>_resident_bytes`) alongside the
        // pre-existing total (`stream_cache_resident_bytes`).  The
        // roll-up is only overlap-safe because the total's name does
        // not start with the stripe prefix — pin that here so a rename
        // can't silently double-count residency.
        let reg = Registry::new();
        reg.gauge("stream_cache_stripe0_resident_bytes").set(100.0);
        reg.gauge("stream_cache_stripe1_resident_bytes").set(40.0);
        reg.gauge("stream_cache_stripe2_resident_bytes").set(0.0);
        reg.gauge("stream_cache_resident_bytes").set(140.0);
        assert_eq!(
            reg.sum_gauges("stream_cache_stripe", "_resident_bytes"),
            140.0,
            "stripes sum; the total gauge must not be counted again"
        );
        assert!(!name_matches(
            "stream_cache_resident_bytes",
            "stream_cache_stripe",
            "_resident_bytes"
        ));
    }

    #[test]
    fn histogram_stats() {
        let reg = Registry::new();
        let h = reg.histogram("lat");
        for i in 1..=100 {
            h.observe(i as f64);
        }
        assert_eq!(h.count(), 100);
        assert!((h.mean() - 50.5).abs() < 1e-9);
        assert!((h.percentile(50.0) - 50.5).abs() < 1.0);
        let snap = reg.snapshot();
        assert_eq!(snap["lat_count"], 100.0);
        assert_eq!(snap["lat_max"], 100.0);
        assert!((snap["lat_p95"] - 95.5).abs() < 1.5, "{}", snap["lat_p95"]);
        assert!(snap["lat_p50"] <= snap["lat_p95"] && snap["lat_p95"] <= snap["lat_p99"]);
    }

    #[test]
    fn poisoned_registry_keeps_reporting() {
        // One thread dying while it holds the registry lock must not
        // take metrics away from every other component in the process.
        let reg = Registry::new();
        reg.counter("alive").inc();
        let reg2 = reg.clone();
        let _ = std::thread::spawn(move || {
            let _g = reg2.inner.lock().unwrap();
            panic!("poison the registry");
        })
        .join();
        reg.counter("alive").inc();
        assert_eq!(reg.snapshot()["alive"], 2.0);
    }

    #[test]
    fn empty_histogram_percentile_is_zero_not_nan() {
        // Satellite fix: an empty window used to return f64::NAN, which
        // silently fails every comparison and is not valid exposition
        // output.  Empty must be a well-defined 0.0 at any quantile.
        let h = Histogram::default();
        for q in [0.0, 50.0, 95.0, 99.0, 100.0] {
            let p = h.percentile(q);
            assert_eq!(p, 0.0, "empty percentile({q}) must be 0.0, got {p}");
            assert!(!p.is_nan());
        }
        assert_eq!(h.sum(), 0.0);
        assert!(h.window().is_empty());
    }

    #[test]
    fn histogram_reset_starts_a_fresh_window() {
        let h = Histogram::default();
        for i in 1..=10 {
            h.observe(i as f64);
        }
        assert_eq!(h.count(), 10);
        assert!((h.sum() - 55.0).abs() < 1e-9);
        h.reset();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile(50.0), 0.0);
        assert!(h.window().is_empty());
        // The handle keeps working after reset — and the ring position
        // restarts, so the new window is exactly the new samples.
        h.observe(7.0);
        h.observe(9.0);
        assert_eq!(h.count(), 2);
        assert_eq!(h.window(), vec![7.0, 9.0]);
        assert!((h.mean() - 8.0).abs() < 1e-9);
    }

    #[test]
    fn registry_enumeration_matches_registrations() {
        let reg = Registry::new();
        reg.counter("b_ctr").add(2);
        reg.counter("a_ctr").inc();
        reg.gauge("g").set(1.5);
        reg.histogram("h").observe(3.0);
        let names: Vec<String> =
            reg.counters().into_iter().map(|(n, _)| n).collect();
        assert_eq!(names, vec!["a_ctr".to_string(), "b_ctr".to_string()]);
        assert_eq!(reg.gauges().len(), 1);
        assert_eq!(reg.histograms().len(), 1);
        // Handles are live views, not copies.
        let (_, c) = &reg.counters()[0];
        c.inc();
        assert_eq!(reg.counter("a_ctr").get(), 2);
    }

    #[test]
    fn histogram_ring_wraps() {
        let h = Histogram::default();
        for i in 0..(RING + 100) {
            h.observe(i as f64);
        }
        assert_eq!(h.count() as usize, RING + 100);
        // p0 of the window should be >= 100 (oldest entries evicted)
        assert!(h.percentile(0.0) >= 99.0);
    }

    #[test]
    fn csv_writer_writes_rows() {
        let path = std::env::temp_dir().join("litl_csv_test.csv");
        let path = path.to_str().unwrap();
        {
            let mut w = CsvWriter::create(path, &["step", "loss"]).unwrap();
            w.row(&[1.0, 0.9]).unwrap();
            w.row(&[2.0, 0.8]).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(path).unwrap();
        assert_eq!(text, "step,loss\n1,0.9\n2,0.8\n");
    }

    #[test]
    fn clones_share_state() {
        let reg = Registry::new();
        let c1 = reg.counter("x");
        let c2 = reg.counter("x");
        c1.inc();
        c2.inc();
        assert_eq!(reg.counter("x").get(), 2);
    }
}
