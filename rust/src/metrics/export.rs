//! Telemetry exporters: Chrome `trace_event` JSON and Prometheus text.
//!
//! Two operator-facing formats, both built on in-tree primitives
//! (`util::json`; no third-party serializers):
//!
//! * [`chrome_trace_json`] — a drained [`TraceReport`] as the Chrome
//!   tracing / Perfetto `trace_event` format: one complete (`"ph":"X"`)
//!   event per span, microsecond `ts`/`dur`, the session-local thread
//!   index as `tid`, and `frame`/`shard` in `args`.  Load the file at
//!   `ui.perfetto.dev` (or `chrome://tracing`) to see the pipeline.
//! * [`prometheus_text`] — the full metrics [`Registry`] in Prometheus
//!   text exposition: counters and gauges verbatim, histograms as
//!   cumulative `_bucket{le="..."}` lines (bounds at the recent-window
//!   p50/p90/p95/p99/max) plus `_sum`/`_count`.  Values are NaN-free
//!   by construction and name collisions are skipped, not emitted.

use std::collections::BTreeSet;
use std::fmt::Write as _;

use crate::metrics::trace::{TraceReport, NO_FRAME, NO_SHARD};
use crate::metrics::{Histogram, Registry};
use crate::util::json::{self, Json};

/// Render a drained trace as Chrome `trace_event` JSON.
pub fn chrome_trace_json(report: &TraceReport) -> String {
    let events: Vec<Json> = report
        .spans
        .iter()
        .map(|s| {
            let frame = if s.frame == NO_FRAME { -1.0 } else { s.frame as f64 };
            let shard = if s.shard == NO_SHARD { -1.0 } else { s.shard as f64 };
            json::obj(vec![
                ("name", Json::Str(s.stage.to_string())),
                ("cat", Json::Str("litl".to_string())),
                ("ph", Json::Str("X".to_string())),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Num(s.tid as f64)),
                ("ts", Json::Num(s.start_ns as f64 / 1e3)),
                ("dur", Json::Num(s.dur_ns as f64 / 1e3)),
                (
                    "args",
                    json::obj(vec![
                        ("frame", Json::Num(frame)),
                        ("shard", Json::Num(shard)),
                    ]),
                ),
            ])
        })
        .collect();
    json::obj(vec![
        ("displayTimeUnit", Json::Str("ms".to_string())),
        ("traceEvents", Json::Arr(events)),
        (
            "otherData",
            json::obj(vec![
                ("dropped", Json::Num(report.dropped as f64)),
                (
                    "unmatched_begins",
                    Json::Num(report.unmatched_begins as f64),
                ),
                ("unmatched_ends", Json::Num(report.unmatched_ends as f64)),
                ("threads", Json::Num(report.threads as f64)),
            ]),
        ),
    ])
    .to_string_compact()
}

/// Write [`chrome_trace_json`] to `path`, creating parent directories.
pub fn write_chrome_trace(path: &str, report: &TraceReport) -> crate::Result<()> {
    if let Some(parent) = std::path::Path::new(path).parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, chrome_trace_json(report))?;
    Ok(())
}

/// Finite-or-zero: exposition output must never contain NaN/inf.
fn finite(x: f64) -> f64 {
    if x.is_finite() {
        x
    } else {
        0.0
    }
}

/// Claim `names` against the emitted set; false (and no claim) if any
/// collides.  Guards against e.g. a counter named `foo_count` clashing
/// with histogram `foo`'s derived `_count` line.
fn claim(seen: &mut BTreeSet<String>, names: &[String]) -> bool {
    if names.iter().any(|n| seen.contains(n)) {
        return false;
    }
    for n in names {
        seen.insert(n.clone());
    }
    true
}

fn write_histogram(out: &mut String, name: &str, h: &Histogram) {
    let _ = writeln!(out, "# TYPE {name} histogram");
    let mut window = h.window();
    window.sort_by(f64::total_cmp);
    let count = h.count();
    // Bucket bounds from the recent window's percentile grid (the
    // ring holds the newest RING samples, so finite buckets describe
    // the recent window; the +Inf bucket carries the lifetime count —
    // cumulative counts stay monotone because window_len <= count).
    let mut emitted_bounds: BTreeSet<String> = BTreeSet::new();
    if !window.is_empty() {
        for q in [50.0, 90.0, 95.0, 99.0, 100.0] {
            let bound = crate::util::stats::percentile(&window, q);
            let label = format!("{}", finite(bound));
            if !emitted_bounds.insert(label.clone()) {
                continue; // duplicate le label: already covered
            }
            let cum = window.iter().filter(|&&x| x <= bound).count();
            let _ = writeln!(out, "{name}_bucket{{le=\"{label}\"}} {cum}");
        }
    }
    let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {count}");
    let _ = writeln!(out, "{name}_sum {}", finite(h.sum()));
    let _ = writeln!(out, "{name}_count {count}");
}

/// Render the full registry as Prometheus text exposition.
pub fn prometheus_text(registry: &Registry) -> String {
    let mut out = String::new();
    let mut seen: BTreeSet<String> = BTreeSet::new();
    for (name, c) in registry.counters() {
        if !claim(&mut seen, std::slice::from_ref(&name)) {
            let _ = writeln!(out, "# skipped duplicate {name}");
            continue;
        }
        let _ = writeln!(out, "# TYPE {name} counter");
        let _ = writeln!(out, "{name} {}", c.get());
    }
    for (name, g) in registry.gauges() {
        if !claim(&mut seen, std::slice::from_ref(&name)) {
            let _ = writeln!(out, "# skipped duplicate {name}");
            continue;
        }
        let _ = writeln!(out, "# TYPE {name} gauge");
        let _ = writeln!(out, "{name} {}", finite(g.get()));
    }
    for (name, h) in registry.histograms() {
        let derived = [
            name.clone(),
            format!("{name}_bucket"),
            format!("{name}_sum"),
            format!("{name}_count"),
        ];
        if !claim(&mut seen, &derived) {
            let _ = writeln!(out, "# skipped duplicate {name}");
            continue;
        }
        write_histogram(&mut out, &name, &h);
    }
    out
}

/// Write [`prometheus_text`] to `path`, creating parent directories.
pub fn write_prometheus(path: &str, registry: &Registry) -> crate::Result<()> {
    if let Some(parent) = std::path::Path::new(path).parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, prometheus_text(registry))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::trace::CompletedSpan;

    fn sample_report() -> TraceReport {
        TraceReport {
            spans: vec![
                CompletedSpan {
                    stage: "schedule",
                    frame: 1,
                    shard: NO_SHARD,
                    tid: 0,
                    start_ns: 1_000,
                    dur_ns: 5_000,
                },
                CompletedSpan {
                    stage: "project",
                    frame: 1,
                    shard: 2,
                    tid: 3,
                    start_ns: 7_500,
                    dur_ns: 2_500,
                },
            ],
            unmatched_begins: 0,
            unmatched_ends: 0,
            dropped: 4,
            threads: 4,
        }
    }

    #[test]
    fn chrome_trace_is_well_formed_and_loadable_shape() {
        let text = chrome_trace_json(&sample_report());
        let doc = Json::parse(&text).expect("emitted JSON must parse");
        assert_eq!(doc.get("displayTimeUnit").and_then(Json::as_str), Some("ms"));
        let events = doc
            .get("traceEvents")
            .and_then(Json::as_arr)
            .expect("traceEvents array");
        assert_eq!(events.len(), 2);
        for ev in events {
            assert_eq!(ev.get("ph").and_then(Json::as_str), Some("X"));
            for key in ["pid", "tid", "ts", "dur"] {
                assert!(
                    ev.get(key).and_then(Json::as_f64).is_some(),
                    "missing numeric {key}"
                );
            }
            assert!(ev.get("name").and_then(Json::as_str).is_some());
        }
        // Microsecond conversion: 5_000 ns span -> dur 5 us.
        assert_eq!(events[0].get("dur").and_then(Json::as_f64), Some(5.0));
        // Shard sentinel becomes -1, real shard passes through.
        let args = events[1].get("args").unwrap();
        assert_eq!(args.get("shard").and_then(Json::as_f64), Some(2.0));
        assert_eq!(
            doc.get("otherData")
                .and_then(|o| o.get("dropped"))
                .and_then(Json::as_f64),
            Some(4.0)
        );
    }

    #[test]
    fn prometheus_dump_round_trips_every_metric_without_collisions() {
        let reg = Registry::new();
        reg.counter("service_frames").add(42);
        reg.gauge("service_queue_depth").set(3.5);
        let h = reg.histogram("service_latency");
        for i in 1..=100 {
            h.observe(i as f64 / 1000.0);
        }
        reg.histogram("stream_gen_ns"); // registered but empty
        let text = prometheus_text(&reg);
        assert!(text.contains("# TYPE service_frames counter"));
        assert!(text.contains("service_frames 42"));
        assert!(text.contains("# TYPE service_queue_depth gauge"));
        assert!(text.contains("service_queue_depth 3.5"));
        assert!(text.contains("# TYPE service_latency histogram"));
        assert!(text.contains("service_latency_count 100"));
        // Empty histogram: well-formed, zero-valued, NaN-free.
        assert!(text.contains("stream_gen_ns_bucket{le=\"+Inf\"} 0"));
        assert!(text.contains("stream_gen_ns_count 0"));
        assert!(!text.contains("NaN") && !text.contains("inf"));

        // Every exposed base name is unique.
        let mut names = BTreeSet::new();
        for line in text.lines().filter(|l| l.starts_with("# TYPE ")) {
            let name = line.split_whitespace().nth(2).unwrap();
            assert!(names.insert(name.to_string()), "duplicate {name}");
        }
        // Histogram bucket lines are cumulative-monotone and end +Inf.
        let buckets: Vec<&str> = text
            .lines()
            .filter(|l| l.starts_with("service_latency_bucket"))
            .collect();
        assert!(buckets.len() >= 2);
        let counts: Vec<f64> = buckets
            .iter()
            .map(|l| l.split_whitespace().last().unwrap().parse().unwrap())
            .collect();
        assert!(counts.windows(2).all(|w| w[0] <= w[1]), "{counts:?}");
        assert!(buckets.last().unwrap().contains("le=\"+Inf\""));
    }

    #[test]
    fn colliding_names_are_skipped_not_duplicated() {
        let reg = Registry::new();
        reg.counter("x").inc();
        reg.gauge("x").set(2.0);
        // Histogram whose derived `_count` collides with a counter.
        reg.counter("lat_count").add(9);
        reg.histogram("lat").observe(1.0);
        let text = prometheus_text(&reg);
        // The counter won the name; the gauge was skipped.
        assert_eq!(
            text.lines().filter(|l| l.starts_with("# TYPE x ")).count(),
            1
        );
        assert!(text.contains("# skipped duplicate x"));
        // The histogram lost to `lat_count` and emitted nothing.
        assert!(text.contains("# skipped duplicate lat"));
        assert!(!text.contains("# TYPE lat histogram"));
    }

    #[test]
    fn non_finite_gauges_are_sanitized() {
        let reg = Registry::new();
        reg.gauge("weird").set(f64::NAN);
        reg.gauge("hot").set(f64::INFINITY);
        let text = prometheus_text(&reg);
        assert!(text.contains("weird 0"));
        assert!(text.contains("hot 0"));
        assert!(!text.contains("NaN"));
    }
}
