//! Frame-level span tracing through the serving pipeline.
//!
//! A *span* is one pipeline stage of one frame on one shard —
//! `begin/end(stage, frame_id, shard)` — recorded into bounded
//! per-thread buffers and drained by a collector into completed spans
//! plus a per-frame stage breakdown.  The design goals, in order:
//!
//! 1. **Zero behavior change when off.**  The pinned determinism suites
//!    (`service_schedule`, `stream_parity`, `topology`, `farm_parity`)
//!    must stay bitwise-identical with tracing disabled, and the
//!    disabled fast path must cost a few relaxed atomic loads — no
//!    locks, no clock reads, no allocation.
//! 2. **Lock-light when on.**  Each emitting thread owns its buffer
//!    (one uncontended mutex per event); the only shared state touched
//!    per event is the level atomic and, on first emit per thread per
//!    session, a registration lock.
//! 3. **Bounded.**  Buffers cap at `ring_events` events per thread;
//!    overflow drops the event and counts it — the drain stays
//!    well-formed no matter how long a session runs.
//!
//! Timestamps come from a [`TraceClock`]: wall monotonic
//! ([`std::time::Instant`]) for live serving, or a [`SimClock`] so
//! simulated-time experiments trace on the same axis their devices
//! charge.  Sessions are process-global ([`TraceSession::begin`]
//! installs one; instrumentation points call the free functions) so
//! deep layers — the bounded queue, the thread pool — need no handle
//! threading.  `finish()` drains every buffer into a [`TraceReport`].
//!
//! Stage taxonomy for the serving path (`coordinator::service`):
//! `request` (client submit → reply) envelopes `admit` → `queue_wait`
//! → `schedule` → `lane_wait` → `project` (per shard) → `gather`.
//! The breakdown attributes `lane_wait`/`project` to the critical
//! shard (the one maximizing their chained duration), so per-frame
//! stage times always sum within the end-to-end request latency.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU32, AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::sim::clock::SimClock;

/// Sentinel frame id for events not attributable to one frame
/// (queue/pool internals, or emission while tracing was off).
pub const NO_FRAME: u64 = u64::MAX;
/// Sentinel shard id for stages that are not shard-local.
pub const NO_SHARD: u32 = u32::MAX;
/// Sentinel start token returned by [`start`] when recording is off.
pub const NO_TOKEN: u64 = u64::MAX;

// Serving-pipeline stages (see module docs for the ordering contract).
pub const STAGE_REQUEST: &str = "request";
pub const STAGE_ADMIT: &str = "admit";
pub const STAGE_QUEUE_WAIT: &str = "queue_wait";
pub const STAGE_SCHEDULE: &str = "schedule";
pub const STAGE_LANE_WAIT: &str = "lane_wait";
pub const STAGE_PROJECT: &str = "project";
pub const STAGE_GATHER: &str = "gather";
// Execution-layer waits (no frame attribution).
pub const STAGE_QUEUE_PUSH_WAIT: &str = "queue_push_wait";
pub const STAGE_QUEUE_POP_WAIT: &str = "queue_pop_wait";
pub const STAGE_POOL_PARK: &str = "pool_park";
// Trainer step-loop stages (frame = step index).
pub const STAGE_TRAIN_FWD: &str = "train_fwd";
pub const STAGE_TRAIN_PROJECT: &str = "train_project";
pub const STAGE_TRAIN_APPLY: &str = "train_apply";
pub const STAGE_DATA_LOAD: &str = "data_load";
// Networked projector client stages (frame = per-client request seq).
pub const STAGE_NET_SEND: &str = "net_send";
pub const STAGE_NET_RECV: &str = "net_recv";
// Session-resume handshake after a redial (frame = resumed cursor).
pub const STAGE_NET_RESUME: &str = "net_resume";

/// How much the tracer does: `Off` (default) is a few atomics,
/// `Summary` enables the profiling hooks (per-stage histograms and the
/// periodic summary line) without buffering events, `Full` additionally
/// records span events for the Chrome-trace export.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Default)]
#[repr(u8)]
pub enum TraceLevel {
    #[default]
    Off = 0,
    Summary = 1,
    Full = 2,
}

impl TraceLevel {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "off" => Ok(TraceLevel::Off),
            "summary" => Ok(TraceLevel::Summary),
            "full" => Ok(TraceLevel::Full),
            other => bail!("trace level must be off|summary|full, got '{other}'"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TraceLevel::Off => "off",
            TraceLevel::Summary => "summary",
            TraceLevel::Full => "full",
        }
    }
}

/// Monotonic time source for span timestamps: wall time anchored at
/// session start, or a shared [`SimClock`] (nanosecond-granular
/// simulated time) so traces line up with device-charged time.
#[derive(Clone)]
pub enum TraceClock {
    Wall(Instant),
    Sim(SimClock),
}

impl TraceClock {
    /// Wall clock anchored now (timestamps are ns since this call).
    pub fn wall() -> Self {
        TraceClock::Wall(Instant::now())
    }

    pub fn sim(clock: SimClock) -> Self {
        TraceClock::Sim(clock)
    }

    fn now_ns(&self) -> u64 {
        match self {
            TraceClock::Wall(epoch) => epoch.elapsed().as_nanos() as u64,
            TraceClock::Sim(c) => (c.now_secs() * 1e9).round() as u64,
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
enum EventKind {
    // Pairing order for equal timestamps: a begin sorts before the
    // complete/end it encloses.
    Begin = 0,
    Complete = 1,
    End = 2,
}

/// One raw ring-buffer entry.  `dur_ns` is meaningful only for
/// `Complete` events (single-thread spans measured at the emit site);
/// `Begin`/`End` pairs are matched by the collector, possibly across
/// threads (e.g. `lane_wait`: begun by the scheduler, ended by the
/// shard worker that pops the job).
#[derive(Clone, Copy, Debug)]
struct SpanEvent {
    stage: &'static str,
    frame: u64,
    shard: u32,
    tid: u32,
    t_ns: u64,
    dur_ns: u64,
    kind: EventKind,
}

struct SpanBuffer {
    tid: u32,
    events: Mutex<Vec<SpanEvent>>,
}

struct SessionInner {
    level: TraceLevel,
    clock: TraceClock,
    ring_events: usize,
    generation: u64,
    buffers: Mutex<Vec<Arc<SpanBuffer>>>,
    next_frame: AtomicU64,
    next_tid: AtomicU32,
    dropped: AtomicU64,
}

/// Fast-path gate: the *only* state the disabled path touches.
static LEVEL: AtomicU8 = AtomicU8::new(0);
/// Session generation — bumped on begin *and* finish so thread-local
/// buffer caches from a previous session never leak into the next.
static GENERATION: AtomicU64 = AtomicU64::new(0);
static ACTIVE: Mutex<Option<Arc<SessionInner>>> = Mutex::new(None);

struct TlsSlot {
    generation: u64,
    session: Arc<SessionInner>,
    buffer: Arc<SpanBuffer>,
}

thread_local! {
    static TLS: RefCell<Option<TlsSlot>> = const { RefCell::new(None) };
}

fn lock_active() -> MutexGuard<'static, Option<Arc<SessionInner>>> {
    // Poison-tolerant, like every lock in the serving path: a panicking
    // emitter must not disable telemetry for the rest of the process.
    ACTIVE.lock().unwrap_or_else(PoisonError::into_inner)
}

/// True when any tracing is on (`summary` or `full`) — gates the
/// profiling hooks (histogram observation, summary lines).
#[inline]
pub fn enabled() -> bool {
    LEVEL.load(Ordering::Relaxed) != 0
}

/// True when span events are being recorded (`full` only).
#[inline]
pub fn recording() -> bool {
    LEVEL.load(Ordering::Relaxed) == TraceLevel::Full as u8
}

/// Run `f` with the calling thread's buffer for the active session,
/// registering one on first use.  Returns `None` when no session is
/// active (or it changed between the level check and here — benign
/// race: the event is simply not recorded).
fn with_session<R>(f: impl FnOnce(&SessionInner, &SpanBuffer) -> R) -> Option<R> {
    TLS.with(|cell| {
        let mut slot = cell.borrow_mut();
        let gen_now = GENERATION.load(Ordering::Acquire);
        let stale = match slot.as_ref() {
            Some(s) => s.generation != gen_now,
            None => true,
        };
        if stale {
            let active = lock_active();
            match active.as_ref() {
                Some(inner) if inner.generation == gen_now => {
                    let tid = inner.next_tid.fetch_add(1, Ordering::Relaxed);
                    let buffer = Arc::new(SpanBuffer {
                        tid,
                        events: Mutex::new(Vec::new()),
                    });
                    inner
                        .buffers
                        .lock()
                        .unwrap_or_else(PoisonError::into_inner)
                        .push(buffer.clone());
                    *slot = Some(TlsSlot {
                        generation: gen_now,
                        session: inner.clone(),
                        buffer,
                    });
                }
                _ => {
                    *slot = None;
                    return None;
                }
            }
        }
        let s = slot.as_ref().expect("slot populated above");
        Some(f(&s.session, &s.buffer))
    })
}

fn push_event(session: &SessionInner, buffer: &SpanBuffer, ev: SpanEvent) {
    let mut events = buffer
        .events
        .lock()
        .unwrap_or_else(PoisonError::into_inner);
    if events.len() >= session.ring_events {
        session.dropped.fetch_add(1, Ordering::Relaxed);
        return;
    }
    events.push(ev);
}

fn record(stage: &'static str, frame: u64, shard: u32, kind: EventKind) {
    with_session(|session, buffer| {
        let t_ns = session.clock.now_ns();
        push_event(
            session,
            buffer,
            SpanEvent {
                stage,
                frame,
                shard,
                tid: buffer.tid,
                t_ns,
                dur_ns: 0,
                kind,
            },
        );
    });
}

/// Next frame id for a new request, or [`NO_FRAME`] when tracing is
/// off.  Ids are session-scoped, dense from 1.
pub fn next_frame() -> u64 {
    if !enabled() {
        return NO_FRAME;
    }
    with_session(|session, _| session.next_frame.fetch_add(1, Ordering::Relaxed) + 1)
        .unwrap_or(NO_FRAME)
}

/// Open a span.  Must be paired with [`end`] on the same
/// `(stage, frame, shard)` key — the pair may close on another thread.
#[inline]
pub fn begin(stage: &'static str, frame: u64, shard: u32) {
    if !recording() {
        return;
    }
    record(stage, frame, shard, EventKind::Begin);
}

/// Close a span opened by [`begin`].
#[inline]
pub fn end(stage: &'static str, frame: u64, shard: u32) {
    if !recording() {
        return;
    }
    record(stage, frame, shard, EventKind::End);
}

/// Start token for a single-thread span; pass to [`complete`].  Costs
/// one atomic load when recording is off.
#[inline]
pub fn start() -> u64 {
    if !recording() {
        return NO_TOKEN;
    }
    with_session(|session, _| session.clock.now_ns()).unwrap_or(NO_TOKEN)
}

/// Record a completed span from a [`start`] token.  Never dangles:
/// the event carries its own duration, so it cannot unbalance a drain
/// (used for waits that may still be open when a session ends).
#[inline]
pub fn complete(stage: &'static str, frame: u64, shard: u32, token: u64) {
    if token == NO_TOKEN || !recording() {
        return;
    }
    with_session(|session, buffer| {
        let now = session.clock.now_ns();
        push_event(
            session,
            buffer,
            SpanEvent {
                stage,
                frame,
                shard,
                tid: buffer.tid,
                t_ns: token,
                dur_ns: now.saturating_sub(token),
                kind: EventKind::Complete,
            },
        );
    });
}

/// A completed (begin..end or self-timed) span.
#[derive(Clone, Copy, Debug)]
pub struct CompletedSpan {
    pub stage: &'static str,
    pub frame: u64,
    pub shard: u32,
    /// Session-local thread index of the *opening* event.
    pub tid: u32,
    pub start_ns: u64,
    pub dur_ns: u64,
}

/// Per-frame stage breakdown; see [`TraceReport::frame_breakdown`].
#[derive(Clone, Debug, Default)]
pub struct FrameBreakdown {
    /// Stage → attributed nanoseconds (critical-shard attribution for
    /// `lane_wait`/`project`, duration sums for the serial stages).
    pub stages: BTreeMap<&'static str, u64>,
    /// End-to-end `request` span duration, when the frame has one.
    pub e2e_ns: Option<u64>,
}

impl FrameBreakdown {
    /// Sum of the attributed stage times.  By construction this is
    /// `<= e2e_ns` for a frame whose stages were recorded within its
    /// request span (the pipeline runs them sequentially and the
    /// parallel shard legs are critical-path attributed).
    pub fn stage_sum_ns(&self) -> u64 {
        self.stages.values().sum()
    }
}

/// Everything a drained session knows.
#[derive(Debug, Default)]
pub struct TraceReport {
    pub spans: Vec<CompletedSpan>,
    /// `begin` events that never saw a matching `end`.
    pub unmatched_begins: u64,
    /// `end` events with no open `begin` (e.g. its begin was dropped
    /// by a full buffer).
    pub unmatched_ends: u64,
    /// Events dropped because a per-thread buffer hit `ring_events`.
    pub dropped: u64,
    /// Emitting threads observed by the session.
    pub threads: u32,
}

impl TraceReport {
    /// Every begin had an end and vice versa.
    pub fn is_balanced(&self) -> bool {
        self.unmatched_begins == 0 && self.unmatched_ends == 0
    }

    /// Group spans by frame and attribute stage time.
    ///
    /// `lane_wait` and `project` run once per shard leg and the legs
    /// run in parallel, so summing them across shards would exceed
    /// wall time.  Instead the breakdown picks the *critical* shard —
    /// the one maximizing `lane_wait + project` — and reports its two
    /// legs; every other stage (which runs serially for a frame) is
    /// summed.  The result: stage times sum within the `request` span.
    pub fn frame_breakdown(&self) -> BTreeMap<u64, FrameBreakdown> {
        let mut out: BTreeMap<u64, FrameBreakdown> = BTreeMap::new();
        // Per frame, per shard: (lane_wait, project) accumulators.
        let mut legs: BTreeMap<u64, HashMap<u32, (u64, u64)>> = BTreeMap::new();
        for s in &self.spans {
            if s.frame == NO_FRAME {
                continue;
            }
            let b = out.entry(s.frame).or_default();
            match s.stage {
                STAGE_REQUEST => {
                    b.e2e_ns = Some(b.e2e_ns.unwrap_or(0).max(s.dur_ns));
                }
                STAGE_LANE_WAIT => {
                    legs.entry(s.frame).or_default().entry(s.shard).or_default().0 +=
                        s.dur_ns;
                }
                STAGE_PROJECT => {
                    legs.entry(s.frame).or_default().entry(s.shard).or_default().1 +=
                        s.dur_ns;
                }
                stage => *b.stages.entry(stage).or_default() += s.dur_ns,
            }
        }
        for (frame, shards) in legs {
            if let Some((lane, project)) =
                shards.values().max_by_key(|(l, p)| l + p).copied()
            {
                let b = out.entry(frame).or_default();
                b.stages.insert(STAGE_LANE_WAIT, lane);
                b.stages.insert(STAGE_PROJECT, project);
            }
        }
        out
    }
}

/// An installed tracing session.  Exactly one is active at a time;
/// beginning a new one supersedes the old (whose buffers drain empty).
pub struct TraceSession {
    inner: Arc<SessionInner>,
}

impl TraceSession {
    /// Install a session process-wide.  `ring_events` bounds each
    /// emitting thread's buffer (clamped to at least 16).
    pub fn begin(level: TraceLevel, clock: TraceClock, ring_events: usize) -> Self {
        let mut active = lock_active();
        let generation = GENERATION.fetch_add(1, Ordering::AcqRel) + 1;
        let inner = Arc::new(SessionInner {
            level,
            clock,
            ring_events: ring_events.max(16),
            generation,
            buffers: Mutex::new(Vec::new()),
            next_frame: AtomicU64::new(0),
            next_tid: AtomicU32::new(0),
            dropped: AtomicU64::new(0),
        });
        *active = Some(inner.clone());
        LEVEL.store(level as u8, Ordering::Release);
        TraceSession { inner }
    }

    pub fn level(&self) -> TraceLevel {
        self.inner.level
    }

    /// Uninstall and drain: pair up begin/end events (sorted on the
    /// session clock), fold in self-timed completes, and count what
    /// did not match.  Events recorded after this call are discarded.
    pub fn finish(self) -> TraceReport {
        {
            let mut active = lock_active();
            let still_ours = matches!(
                active.as_ref(),
                Some(cur) if cur.generation == self.inner.generation
            );
            if still_ours {
                LEVEL.store(0, Ordering::Release);
                *active = None;
                // Invalidate thread-local caches pointing at us.
                GENERATION.fetch_add(1, Ordering::AcqRel);
            }
        }
        let buffers = self
            .inner
            .buffers
            .lock()
            .unwrap_or_else(PoisonError::into_inner);
        let mut events: Vec<SpanEvent> = Vec::new();
        for buf in buffers.iter() {
            let mut e = buf.events.lock().unwrap_or_else(PoisonError::into_inner);
            events.append(&mut e);
        }
        let threads = self.inner.next_tid.load(Ordering::Relaxed);
        let dropped = self.inner.dropped.load(Ordering::Relaxed);
        drop(buffers);

        events.sort_by_key(|e| (e.t_ns, e.kind));
        let mut open: HashMap<(&'static str, u64, u32), Vec<(u64, u32)>> =
            HashMap::new();
        let mut spans = Vec::new();
        let mut unmatched_ends = 0u64;
        for ev in &events {
            match ev.kind {
                EventKind::Complete => spans.push(CompletedSpan {
                    stage: ev.stage,
                    frame: ev.frame,
                    shard: ev.shard,
                    tid: ev.tid,
                    start_ns: ev.t_ns,
                    dur_ns: ev.dur_ns,
                }),
                EventKind::Begin => open
                    .entry((ev.stage, ev.frame, ev.shard))
                    .or_default()
                    .push((ev.t_ns, ev.tid)),
                EventKind::End => {
                    match open
                        .get_mut(&(ev.stage, ev.frame, ev.shard))
                        .and_then(Vec::pop)
                    {
                        Some((t0, tid)) => spans.push(CompletedSpan {
                            stage: ev.stage,
                            frame: ev.frame,
                            shard: ev.shard,
                            tid,
                            start_ns: t0,
                            dur_ns: ev.t_ns.saturating_sub(t0),
                        }),
                        None => unmatched_ends += 1,
                    }
                }
            }
        }
        let unmatched_begins =
            open.values().map(|v| v.len() as u64).sum::<u64>();
        spans.sort_by_key(|s| (s.start_ns, s.tid));
        TraceReport {
            spans,
            unmatched_begins,
            unmatched_ends,
            dropped,
            threads,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The session is process-global; tests in this module serialize on
    // one lock so their sessions never overlap.
    static SESSION_LOCK: Mutex<()> = Mutex::new(());

    fn locked() -> MutexGuard<'static, ()> {
        SESSION_LOCK.lock().unwrap_or_else(PoisonError::into_inner)
    }

    #[test]
    fn level_parse_round_trips() {
        for lvl in [TraceLevel::Off, TraceLevel::Summary, TraceLevel::Full] {
            assert_eq!(TraceLevel::parse(lvl.name()).unwrap(), lvl);
        }
        assert!(TraceLevel::parse("verbose").is_err());
        assert!(TraceLevel::Off < TraceLevel::Summary);
        assert!(TraceLevel::Summary < TraceLevel::Full);
    }

    #[test]
    fn disabled_emits_nothing_and_frames_are_sentinel() {
        let _g = locked();
        assert!(!enabled());
        assert_eq!(next_frame(), NO_FRAME);
        begin(STAGE_SCHEDULE, 1, 0);
        end(STAGE_SCHEDULE, 1, 0);
        assert_eq!(start(), NO_TOKEN);
        // A later session must not see any of the above.
        let session =
            TraceSession::begin(TraceLevel::Full, TraceClock::wall(), 1024);
        let report = session.finish();
        assert!(report.spans.is_empty());
        assert!(report.is_balanced());
        assert_eq!(report.dropped, 0);
    }

    #[test]
    fn summary_level_enables_hooks_but_records_no_events() {
        let _g = locked();
        let session =
            TraceSession::begin(TraceLevel::Summary, TraceClock::wall(), 1024);
        assert!(enabled());
        assert!(!recording());
        assert_ne!(next_frame(), NO_FRAME);
        begin(STAGE_SCHEDULE, 1, 0);
        end(STAGE_SCHEDULE, 1, 0);
        let report = session.finish();
        assert!(report.spans.is_empty());
        assert!(!enabled());
    }

    #[test]
    fn begin_end_pairs_into_spans_with_simclock_time() {
        let _g = locked();
        let clock = SimClock::new();
        let session = TraceSession::begin(
            TraceLevel::Full,
            TraceClock::sim(clock.clone()),
            1024,
        );
        let f = next_frame();
        begin(STAGE_SCHEDULE, f, NO_SHARD);
        clock.advance_secs(0.5);
        end(STAGE_SCHEDULE, f, NO_SHARD);
        let tok = start();
        clock.advance_secs(0.25);
        complete(STAGE_PROJECT, f, 3, tok);
        let report = session.finish();
        assert!(report.is_balanced(), "{report:?}");
        assert_eq!(report.spans.len(), 2);
        let sched = report
            .spans
            .iter()
            .find(|s| s.stage == STAGE_SCHEDULE)
            .unwrap();
        assert_eq!(sched.dur_ns, 500_000_000);
        assert_eq!(sched.frame, f);
        let proj = report
            .spans
            .iter()
            .find(|s| s.stage == STAGE_PROJECT)
            .unwrap();
        assert_eq!(proj.dur_ns, 250_000_000);
        assert_eq!(proj.shard, 3);
    }

    #[test]
    fn cross_thread_pairs_match_by_key() {
        let _g = locked();
        let clock = SimClock::new();
        let session = TraceSession::begin(
            TraceLevel::Full,
            TraceClock::sim(clock.clone()),
            1024,
        );
        begin(STAGE_LANE_WAIT, 7, 2);
        clock.advance_secs(0.1);
        std::thread::spawn(|| end(STAGE_LANE_WAIT, 7, 2))
            .join()
            .unwrap();
        let report = session.finish();
        assert!(report.is_balanced(), "{report:?}");
        assert_eq!(report.spans.len(), 1);
        assert_eq!(report.spans[0].dur_ns, 100_000_000);
        // Two threads emitted: this one and the spawned closer.
        assert_eq!(report.threads, 2);
    }

    #[test]
    fn overflow_drops_events_but_the_drain_stays_well_formed() {
        let _g = locked();
        let session =
            TraceSession::begin(TraceLevel::Full, TraceClock::wall(), 16);
        for i in 0..100u64 {
            begin(STAGE_SCHEDULE, i, NO_SHARD);
            end(STAGE_SCHEDULE, i, NO_SHARD);
        }
        let report = session.finish();
        assert!(report.dropped > 0, "expected drops at ring_events=16");
        // 16 buffered events = 8 complete pairs: nothing corrupt, and
        // accounting is exact (buffered + dropped = emitted).
        assert_eq!(report.spans.len() * 2 + report.unmatched_begins as usize, 16);
        assert_eq!(
            report.spans.len() * 2
                + report.unmatched_begins as usize
                + report.dropped as usize,
            200
        );
        assert_eq!(report.unmatched_ends, 0);
    }

    #[test]
    fn unmatched_events_are_counted_not_fabricated() {
        let _g = locked();
        let session =
            TraceSession::begin(TraceLevel::Full, TraceClock::wall(), 1024);
        begin(STAGE_GATHER, 1, NO_SHARD);
        end(STAGE_GATHER, 2, NO_SHARD); // different frame: no match
        let report = session.finish();
        assert_eq!(report.spans.len(), 0);
        assert_eq!(report.unmatched_begins, 1);
        assert_eq!(report.unmatched_ends, 1);
        assert!(!report.is_balanced());
    }

    #[test]
    fn breakdown_attributes_parallel_legs_to_the_critical_shard() {
        let _g = locked();
        let clock = SimClock::new();
        let session = TraceSession::begin(
            TraceLevel::Full,
            TraceClock::sim(clock.clone()),
            1024,
        );
        let f = next_frame();
        // request: 0 .. 1.0s
        begin(STAGE_REQUEST, f, NO_SHARD);
        // schedule: 0 .. 0.1s
        begin(STAGE_SCHEDULE, f, NO_SHARD);
        clock.advance_secs(0.1);
        end(STAGE_SCHEDULE, f, NO_SHARD);
        // shard 0 leg: lane 0.1s, project 0.2s; shard 1 leg: lane
        // 0.05s, project 0.4s (critical: 0.45s total).  The sim clock
        // is one global axis, so the "parallel" legs are laid out
        // sequentially here — the breakdown only reads durations.
        begin(STAGE_LANE_WAIT, f, 0);
        begin(STAGE_LANE_WAIT, f, 1);
        clock.advance_secs(0.05);
        end(STAGE_LANE_WAIT, f, 1);
        clock.advance_secs(0.05);
        end(STAGE_LANE_WAIT, f, 0);
        let t0 = start();
        clock.advance_secs(0.2);
        complete(STAGE_PROJECT, f, 0, t0);
        let t1 = start();
        clock.advance_secs(0.4);
        complete(STAGE_PROJECT, f, 1, t1);
        // gather: 0.05s
        let tg = start();
        clock.advance_secs(0.05);
        complete(STAGE_GATHER, f, NO_SHARD, tg);
        clock.advance_secs(0.25);
        end(STAGE_REQUEST, f, NO_SHARD);
        let report = session.finish();
        assert!(report.is_balanced(), "{report:?}");
        let frames = report.frame_breakdown();
        let b = &frames[&f];
        assert_eq!(b.e2e_ns, Some(1_100_000_000));
        // Critical shard is 1: lane 0.05s + project 0.4s.
        assert_eq!(b.stages[STAGE_LANE_WAIT], 50_000_000);
        assert_eq!(b.stages[STAGE_PROJECT], 400_000_000);
        assert_eq!(b.stages[STAGE_SCHEDULE], 100_000_000);
        assert_eq!(b.stages[STAGE_GATHER], 50_000_000);
        assert!(b.stage_sum_ns() <= b.e2e_ns.unwrap());
    }

    #[test]
    fn a_new_session_supersedes_the_old_one() {
        let _g = locked();
        let s1 = TraceSession::begin(TraceLevel::Full, TraceClock::wall(), 64);
        begin(STAGE_SCHEDULE, 1, NO_SHARD);
        end(STAGE_SCHEDULE, 1, NO_SHARD);
        let s2 = TraceSession::begin(TraceLevel::Full, TraceClock::wall(), 64);
        begin(STAGE_GATHER, 2, NO_SHARD);
        end(STAGE_GATHER, 2, NO_SHARD);
        // s2 sees only its own events; finishing stale s1 afterwards
        // must not disturb the live level (s2 finished first here).
        let r2 = s2.finish();
        assert_eq!(r2.spans.len(), 1);
        assert_eq!(r2.spans[0].stage, STAGE_GATHER);
        let r1 = s1.finish();
        assert_eq!(r1.spans.len(), 1);
        assert_eq!(r1.spans[0].stage, STAGE_SCHEDULE);
        assert!(!enabled());
    }
}
