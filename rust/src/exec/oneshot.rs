//! Single-value rendezvous channel (the reply side of a projection
//! request: submit → OPU frame → `Reply::wait()`).
//!
//! All slot locks are poison-tolerant (`unwrap_or_else
//! (PoisonError::into_inner)`): the guarded state is a plain
//! `Option<Option<T>>` with no invariant that a mid-update panic could
//! break, and a client thread that panics around its `Reply` must never
//! turn into a second panic inside the service worker that later calls
//! `send` on the same slot — that worker is shared by every other
//! client on the shard.

use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::time::Duration;

struct Slot<T> {
    value: Mutex<Option<Option<T>>>, // None = pending; Some(None) = dropped
    cv: Condvar,
}

/// Sending half: consumed by `send`; dropping it unblocks the receiver
/// with `None`.
pub struct Sender<T> {
    slot: Arc<Slot<T>>,
    sent: bool,
}

/// Receiving half.
pub struct Reply<T> {
    slot: Arc<Slot<T>>,
}

/// Create a connected (Sender, Reply) pair.
pub fn channel<T>() -> (Sender<T>, Reply<T>) {
    let slot = Arc::new(Slot {
        value: Mutex::new(None),
        cv: Condvar::new(),
    });
    (
        Sender {
            slot: slot.clone(),
            sent: false,
        },
        Reply { slot },
    )
}

impl<T> Sender<T> {
    pub fn send(mut self, value: T) {
        let mut guard = self.slot.value.lock().unwrap_or_else(PoisonError::into_inner);
        *guard = Some(Some(value));
        self.sent = true;
        self.slot.cv.notify_all();
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        if !self.sent {
            let mut guard = self.slot.value.lock().unwrap_or_else(PoisonError::into_inner);
            if guard.is_none() {
                *guard = Some(None);
                self.slot.cv.notify_all();
            }
        }
    }
}

impl<T> Reply<T> {
    /// Block until the value arrives; `None` if the sender was dropped.
    pub fn wait(self) -> Option<T> {
        let mut guard = self.slot.value.lock().unwrap_or_else(PoisonError::into_inner);
        loop {
            if let Some(v) = guard.take() {
                return v;
            }
            guard = self.slot.cv.wait(guard).unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Wait with a timeout; `Err(self)` lets the caller retry.
    pub fn wait_timeout(self, timeout: Duration) -> Result<Option<T>, Reply<T>> {
        let deadline = std::time::Instant::now() + timeout;
        let mut guard = self.slot.value.lock().unwrap_or_else(PoisonError::into_inner);
        loop {
            if let Some(v) = guard.take() {
                return Ok(v);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                drop(guard);
                return Err(self);
            }
            let (g, _) = self
                .slot
                .cv
                .wait_timeout(guard, deadline - now)
                .unwrap_or_else(PoisonError::into_inner);
            guard = g;
        }
    }

    /// Non-blocking poll.
    pub fn try_take(self) -> Result<Option<T>, Reply<T>> {
        let mut guard = self.slot.value.lock().unwrap_or_else(PoisonError::into_inner);
        if let Some(v) = guard.take() {
            Ok(v)
        } else {
            drop(guard);
            Err(self)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn send_then_wait() {
        let (tx, rx) = channel();
        tx.send(42);
        assert_eq!(rx.wait(), Some(42));
    }

    #[test]
    fn wait_blocks_until_send() {
        let (tx, rx) = channel();
        let handle = thread::spawn(move || rx.wait());
        thread::sleep(Duration::from_millis(20));
        tx.send("done");
        assert_eq!(handle.join().unwrap(), Some("done"));
    }

    #[test]
    fn dropped_sender_yields_none() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        assert_eq!(rx.wait(), None);
    }

    #[test]
    fn poisoned_slot_still_delivers() {
        // A panic while holding the slot lock (a client dying mid-frame)
        // must not cascade into the worker calling `send` later.
        let (tx, rx) = channel::<u32>();
        let slot = rx.slot.clone();
        let _ = thread::spawn(move || {
            let _guard = slot.value.lock().unwrap();
            panic!("poison the reply slot");
        })
        .join();
        tx.send(9);
        assert_eq!(rx.wait(), Some(9));
    }

    #[test]
    fn poisoned_slot_still_reports_a_dropped_sender() {
        let (tx, rx) = channel::<u32>();
        let slot = rx.slot.clone();
        let _ = thread::spawn(move || {
            let _guard = slot.value.lock().unwrap();
            panic!("poison the reply slot");
        })
        .join();
        drop(tx);
        assert_eq!(rx.wait(), None);
    }

    #[test]
    fn timeout_returns_reply_for_retry() {
        let (tx, rx) = channel::<u32>();
        let rx = match rx.wait_timeout(Duration::from_millis(10)) {
            Err(rx) => rx,
            Ok(_) => panic!("should have timed out"),
        };
        tx.send(7);
        assert_eq!(rx.wait(), Some(7));
    }
}
