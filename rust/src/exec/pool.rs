//! Fixed-size worker thread pool with panic containment.
//!
//! Jobs are `FnOnce() + Send` closures; a worker that catches a panicking
//! job logs it and keeps serving (failure injection tests rely on this).
//! `join()` blocks until all submitted jobs completed.

use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use super::queue::BoundedQueue;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    queue: BoundedQueue<Job>,
    workers: Vec<JoinHandle<()>>,
    pending: Arc<AtomicUsize>,
    panics: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(threads: usize, queue_depth: usize) -> Self {
        let queue: BoundedQueue<Job> = BoundedQueue::new(queue_depth.max(1));
        let pending = Arc::new(AtomicUsize::new(0));
        let panics = Arc::new(AtomicUsize::new(0));
        let workers = (0..threads.max(1))
            .map(|i| {
                let q = queue.clone();
                let pending = pending.clone();
                let panics = panics.clone();
                std::thread::Builder::new()
                    .name(format!("litl-worker-{i}"))
                    .spawn(move || {
                        while let Some(job) = q.pop() {
                            let result =
                                std::panic::catch_unwind(AssertUnwindSafe(job));
                            if result.is_err() {
                                panics.fetch_add(1, Ordering::SeqCst);
                                log::error!("worker {i}: job panicked (contained)");
                            }
                            pending.fetch_sub(1, Ordering::SeqCst);
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool {
            queue,
            workers,
            pending,
            panics,
        }
    }

    /// Submit a job (blocks if the queue is full — backpressure).
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        self.pending.fetch_add(1, Ordering::SeqCst);
        if self.queue.push(Box::new(job)).is_err() {
            self.pending.fetch_sub(1, Ordering::SeqCst);
            panic!("submit on closed pool");
        }
    }

    /// Busy-wait (with yield) until all submitted jobs finished.
    pub fn join(&self) {
        while self.pending.load(Ordering::SeqCst) > 0 {
            std::thread::yield_now();
        }
    }

    /// Number of jobs that panicked since pool creation.
    pub fn panic_count(&self) -> usize {
        self.panics.load(Ordering::SeqCst)
    }

    /// Close the queue and join all workers.
    pub fn shutdown(mut self) {
        self.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4, 16);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = counter.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn contains_panics_and_keeps_working() {
        let pool = ThreadPool::new(2, 8);
        let counter = Arc::new(AtomicU64::new(0));
        for i in 0..20 {
            let c = counter.clone();
            pool.submit(move || {
                if i % 5 == 0 {
                    panic!("injected failure {i}");
                }
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 16);
        assert_eq!(pool.panic_count(), 4);
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let pool = ThreadPool::new(2, 4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..8 {
            let c = counter.clone();
            pool.submit(move || {
                std::thread::sleep(std::time::Duration::from_millis(5));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        pool.shutdown();
        assert_eq!(counter.load(Ordering::SeqCst), 8);
    }
}
