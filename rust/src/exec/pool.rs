//! Fixed-size worker thread pool with panic containment and a scoped
//! submit/join API.
//!
//! Jobs are `FnOnce() + Send` closures; a worker that catches a
//! panicking job counts it (optionally into a metrics [`Registry`]) and
//! keeps serving — failure-injection tests and the [`ProjectorFarm`]'s
//! shard observability rely on this.  The pending count is decremented
//! by a drop guard, so `join()` drains even when jobs panic.
//!
//! [`ThreadPool::scope`] is the farm's execution primitive: closures
//! submitted inside a scope may borrow from the caller's stack (the
//! shard devices, the shared input batch, per-shard output slots);
//! `scope` does not return until every scoped job has finished.  Both
//! waiting threads and submitters facing a full queue *help*: they pull
//! queued jobs and run them inline, which bounds memory like classic
//! backpressure while keeping nested scopes (a scoped job opening its
//! own scope on the same pool) deadlock-free on a bounded worker set.
//!
//! [`ProjectorFarm`]: crate::coordinator::farm::ProjectorFarm

use std::marker::PhantomData;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::thread::JoinHandle;

use crate::metrics::trace::{self, NO_FRAME, NO_SHARD, NO_TOKEN};
use crate::metrics::{Counter, Registry};

use super::queue::{BoundedQueue, TryPushError};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Metric name for contained job panics (see `with_registry`).
pub const PANIC_COUNTER: &str = "pool_job_panics";

/// Completion-parkable counter: waiters sleep on the condvar instead of
/// spinning (jobs are matmul-block/shard sized, so the per-job lock is
/// noise next to the work it brackets).
#[derive(Default)]
struct Tally {
    count: Mutex<usize>,
    zero: Condvar,
}

impl Tally {
    fn add_one(&self) {
        *self.count.lock().unwrap_or_else(PoisonError::into_inner) += 1;
    }

    fn sub_one(&self) {
        let mut c = self.count.lock().unwrap_or_else(PoisonError::into_inner);
        *c -= 1;
        if *c == 0 {
            self.zero.notify_all();
        }
    }

    fn read(&self) -> usize {
        *self.count.lock().unwrap_or_else(PoisonError::into_inner)
    }
}

#[derive(Clone)]
struct Shared {
    queue: BoundedQueue<Job>,
    pending: Arc<Tally>,
    panics: Arc<AtomicUsize>,
    panic_metric: Option<Counter>,
}

impl Shared {
    /// Run one job with drain-on-panic semantics: the pending count is
    /// decremented by a drop guard so `join()` always terminates.
    fn run_job(&self, job: Job) {
        struct Pending<'a>(&'a Tally);
        impl Drop for Pending<'_> {
            fn drop(&mut self) {
                self.0.sub_one();
            }
        }
        let _guard = Pending(&self.pending);
        if std::panic::catch_unwind(AssertUnwindSafe(job)).is_err() {
            self.panics.fetch_add(1, Ordering::SeqCst);
            if let Some(metric) = &self.panic_metric {
                metric.inc();
            }
            log::error!("pool: job panicked (contained)");
        }
    }

    /// Help-then-park: drain the queue from this thread, then sleep on
    /// the tally until it reaches zero.  Any job submitted before this
    /// call is either drained here, already running on a worker, or
    /// finished — so parking cannot strand work (jobs submitted *by*
    /// running jobs are the submitters' responsibility: `submit` helps
    /// on a full queue and workers drain the rest).
    fn help_then_park(&self, tally: &Tally) {
        // One self-timed `pool_park` span covers this call's wait phase
        // (from the first blocked iteration until the tally drains); a
        // call that never blocks never touches the tracer.
        let mut park = NO_TOKEN;
        loop {
            while let Some(job) = self.queue.try_pop() {
                self.run_job(job);
            }
            let c = tally.count.lock().unwrap_or_else(PoisonError::into_inner);
            if *c == 0 {
                drop(c);
                trace::complete(trace::STAGE_POOL_PARK, NO_FRAME, NO_SHARD, park);
                return;
            }
            if park == NO_TOKEN {
                park = trace::start();
            }
            // Park briefly; the 1 ms timeout bounds how long we go
            // without re-checking the queue, since a running job may
            // push follow-up work after our drain.
            let (guard, _) = tally
                .zero
                .wait_timeout(c, std::time::Duration::from_millis(1))
                .unwrap_or_else(PoisonError::into_inner);
            if *guard == 0 {
                drop(guard);
                trace::complete(trace::STAGE_POOL_PARK, NO_FRAME, NO_SHARD, park);
                return;
            }
        }
    }
}

pub struct ThreadPool {
    shared: Shared,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
}

impl ThreadPool {
    pub fn new(threads: usize, queue_depth: usize) -> Self {
        Self::build(threads, queue_depth, None)
    }

    /// Like [`ThreadPool::new`], surfacing the panic count as the
    /// [`PANIC_COUNTER`] counter of `registry` so shard failures are
    /// observable alongside the service metrics.
    pub fn with_registry(threads: usize, queue_depth: usize, registry: &Registry) -> Self {
        Self::build(threads, queue_depth, Some(registry.counter(PANIC_COUNTER)))
    }

    fn build(threads: usize, queue_depth: usize, panic_metric: Option<Counter>) -> Self {
        let shared = Shared {
            queue: BoundedQueue::new(queue_depth.max(1)),
            pending: Arc::new(Tally::default()),
            panics: Arc::new(AtomicUsize::new(0)),
            panic_metric,
        };
        let threads = threads.max(1);
        let workers = (0..threads)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("litl-worker-{i}"))
                    .spawn(move || {
                        while let Some(job) = shared.queue.pop() {
                            shared.run_job(job);
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool {
            shared,
            workers,
            threads,
        }
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Submit a job (blocks if the queue is full — backpressure).
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        self.submit_boxed(Box::new(job));
    }

    fn submit_boxed(&self, job: Job) {
        self.shared.pending.add_one();
        let mut job = job;
        loop {
            match self.shared.queue.try_push(job) {
                Ok(()) => return,
                Err(TryPushError::Closed(_)) => {
                    self.shared.pending.sub_one();
                    panic!("submit on closed pool");
                }
                Err(TryPushError::Full(rejected)) => {
                    // Backpressure by helping: run one queued job on this
                    // thread instead of blocking.  Keeps memory bounded
                    // AND keeps nested scopes deadlock-free when every
                    // worker is itself trying to submit (e.g. farm shard
                    // jobs fanning out pooled matmuls on the same pool).
                    job = rejected;
                    match self.shared.queue.try_pop() {
                        Some(other) => self.shared.run_job(other),
                        None => std::thread::yield_now(),
                    }
                }
            }
        }
    }

    /// Wait until all submitted jobs finished: helps run queued jobs
    /// from the calling thread, then parks on a condvar for the in-flight
    /// tail (no busy spin).  Jobs that panicked still drain (their
    /// pending slot is released by a drop guard).
    pub fn join(&self) {
        self.shared.help_then_park(&self.shared.pending);
    }

    /// Number of jobs that panicked since pool creation.
    pub fn panic_count(&self) -> usize {
        self.shared.panics.load(Ordering::SeqCst)
    }

    /// Run `f` with a [`Scope`] that can submit borrowing jobs; returns
    /// after every scoped job has completed.  Scoped jobs may borrow
    /// anything that outlives the `scope` call (`'env`), which is what
    /// lets the projector farm hand each shard a reference to the shared
    /// input batch and a `&mut` slot for its output.
    pub fn scope<'env, F, R>(&self, f: F) -> R
    where
        F: for<'pool> FnOnce(&Scope<'env, 'pool>) -> R,
    {
        let scope = Scope {
            pool: self,
            tracked: Arc::new(Tally::default()),
            _env: PhantomData,
        };
        // Run the scope body, then wait for all scoped jobs even if the
        // body panicked — the borrows end when `scope` returns, so no
        // job may still be running (or queued) past this point.
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| f(&scope)));
        scope.wait();
        match result {
            Ok(r) => r,
            Err(payload) => std::panic::resume_unwind(payload),
        }
    }

    /// Close the queue and join all workers.
    pub fn shutdown(mut self) {
        self.shared.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Handle for submitting stack-borrowing jobs; see [`ThreadPool::scope`].
pub struct Scope<'env, 'pool> {
    pool: &'pool ThreadPool,
    tracked: Arc<Tally>,
    /// Invariant over `'env`: disallows shrinking the borrow lifetime.
    _env: PhantomData<&'env mut &'env ()>,
}

impl<'env> Scope<'env, '_> {
    /// Submit a job that may borrow from `'env`.  The job is tracked by
    /// this scope; `ThreadPool::scope` joins it before returning.
    pub fn submit<F>(&self, job: F)
    where
        F: FnOnce() + Send + 'env,
    {
        struct Tracked(Arc<Tally>);
        impl Drop for Tracked {
            fn drop(&mut self) {
                self.0.sub_one();
            }
        }
        self.tracked.add_one();
        let tracker = Tracked(self.tracked.clone());
        let wrapped = move || {
            let _tracker = tracker;
            job();
        };
        let boxed: Box<dyn FnOnce() + Send + 'env> = Box::new(wrapped);
        // SAFETY: `wait()` (called by `ThreadPool::scope` before it
        // returns) blocks until this job has run or been dropped, so the
        // closure never outlives the `'env` borrows it captures.  The
        // tracker decrements on drop, covering the dropped-without-run
        // case (closed queue) as well as panics.
        let boxed: Job = unsafe {
            std::mem::transmute::<
                Box<dyn FnOnce() + Send + 'env>,
                Box<dyn FnOnce() + Send + 'static>,
            >(boxed)
        };
        self.pool.submit_boxed(boxed);
    }

    /// Jobs submitted through this scope and not yet finished.
    pub fn outstanding(&self) -> usize {
        self.tracked.read()
    }

    fn wait(&self) {
        self.pool.shared.help_then_park(&self.tracked);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4, 16);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = counter.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn contains_panics_and_keeps_working() {
        let pool = ThreadPool::new(2, 8);
        let counter = Arc::new(AtomicU64::new(0));
        for i in 0..20 {
            let c = counter.clone();
            pool.submit(move || {
                if i % 5 == 0 {
                    panic!("injected failure {i}");
                }
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 16);
        assert_eq!(pool.panic_count(), 4);
    }

    #[test]
    fn join_drains_when_every_job_panics() {
        // The satellite case: pending must reach zero even when all jobs
        // panic, so join() terminates and the panic count is exact.
        let pool = ThreadPool::new(2, 4);
        for i in 0..12 {
            pool.submit(move || panic!("boom {i}"));
        }
        pool.join();
        assert_eq!(pool.panic_count(), 12);
    }

    #[test]
    fn panics_surface_through_metrics_registry() {
        let registry = Registry::new();
        let pool = ThreadPool::with_registry(2, 4, &registry);
        for _ in 0..3 {
            pool.submit(|| panic!("observable failure"));
        }
        pool.submit(|| {});
        pool.join();
        assert_eq!(registry.snapshot()[PANIC_COUNTER], 3.0);
        assert_eq!(pool.panic_count(), 3);
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let pool = ThreadPool::new(2, 4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..8 {
            let c = counter.clone();
            pool.submit(move || {
                std::thread::sleep(std::time::Duration::from_millis(5));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        pool.shutdown();
        assert_eq!(counter.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn scope_jobs_borrow_the_stack() {
        let pool = ThreadPool::new(4, 16);
        let input: Vec<u64> = (0..64).collect();
        let mut partials = vec![0u64; 8];
        pool.scope(|s| {
            for (block, slot) in input.chunks(8).zip(partials.iter_mut()) {
                s.submit(move || {
                    *slot = block.iter().sum();
                });
            }
        });
        assert_eq!(partials.iter().sum::<u64>(), input.iter().sum::<u64>());
    }

    #[test]
    fn scope_waits_for_slow_jobs() {
        let pool = ThreadPool::new(2, 8);
        let mut flags = [false; 6];
        pool.scope(|s| {
            for flag in flags.iter_mut() {
                s.submit(move || {
                    std::thread::sleep(std::time::Duration::from_millis(10));
                    *flag = true;
                });
            }
        });
        assert!(flags.iter().all(|&f| f));
    }

    #[test]
    fn scope_drains_panicking_jobs() {
        let pool = ThreadPool::new(2, 8);
        let mut results = vec![0u32; 5];
        pool.scope(|s| {
            for (i, slot) in results.iter_mut().enumerate() {
                s.submit(move || {
                    if i == 2 {
                        panic!("shard failure injection");
                    }
                    *slot = i as u32 + 1;
                });
            }
        });
        assert_eq!(results, vec![1, 2, 0, 4, 5]);
        assert_eq!(pool.panic_count(), 1);
    }

    #[test]
    fn sequential_scopes_reuse_the_pool() {
        let pool = ThreadPool::new(1, 32);
        let mut totals = vec![0u64; 3];
        pool.scope(|outer| {
            for (i, slot) in totals.iter_mut().enumerate() {
                outer.submit(move || {
                    *slot = (i as u64 + 1) * 10;
                });
            }
        });
        let mut doubled = vec![0u64; 3];
        pool.scope(|s| {
            for (src, dst) in totals.iter().zip(doubled.iter_mut()) {
                s.submit(move || {
                    *dst = src * 2;
                });
            }
        });
        assert_eq!(doubled, vec![20, 40, 60]);
    }

    #[test]
    fn scope_inside_a_pool_job_does_not_deadlock() {
        // The hard case: one worker, tiny queue, and the scoped job
        // itself opens a scope on the same pool and over-fills the
        // queue.  `submit` must help (run queued jobs) when the queue
        // is full, or the lone worker blocks forever on push.
        let pool = ThreadPool::new(1, 2);
        let total = AtomicU64::new(0);
        let pool_ref = &pool;
        let total_ref = &total;
        pool.scope(|outer| {
            outer.submit(move || {
                let mut inner_vals = [0u64; 8];
                pool_ref.scope(|inner| {
                    for (i, slot) in inner_vals.iter_mut().enumerate() {
                        inner.submit(move || {
                            *slot = i as u64 + 1;
                        });
                    }
                });
                total_ref.fetch_add(inner_vals.iter().sum::<u64>(), Ordering::SeqCst);
            });
        });
        assert_eq!(total.load(Ordering::SeqCst), 36);
    }
}
