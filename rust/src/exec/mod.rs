//! Execution substrate: std-only async building blocks.
//!
//! tokio is not available in the offline vendor set, so the coordinator's
//! concurrency is built on these primitives:
//!
//! * [`queue::BoundedQueue`] — MPMC blocking queue with backpressure and
//!   close semantics (the projection service's request channel).
//! * [`oneshot`] — single-value rendezvous (projection replies).
//! * [`pool::ThreadPool`] — fixed worker pool with panic containment
//!   (per-layer asynchronous DFA updates, parallel data generation).
//! * [`CancelToken`] — cooperative cancellation shared across workers.

pub mod oneshot;
pub mod pool;
pub mod queue;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Cooperative cancellation flag.
#[derive(Clone, Default)]
pub struct CancelToken {
    flag: Arc<AtomicBool>,
}

impl CancelToken {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn cancel(&self) {
        self.flag.store(true, Ordering::SeqCst);
    }

    pub fn is_cancelled(&self) -> bool {
        self.flag.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cancel_token_propagates() {
        let t = CancelToken::new();
        let t2 = t.clone();
        assert!(!t2.is_cancelled());
        t.cancel();
        assert!(t2.is_cancelled());
    }
}
