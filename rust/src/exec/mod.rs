//! Execution substrate: std-only async building blocks.
//!
//! tokio is not available in the offline vendor set, so the coordinator's
//! concurrency is built on these primitives:
//!
//! * [`queue::BoundedQueue`] — MPMC blocking queue with backpressure and
//!   close semantics (the projection service's request channel).
//! * [`oneshot`] — single-value rendezvous (projection replies).
//! * [`pool::ThreadPool`] — fixed worker pool with panic containment
//!   (per-layer asynchronous DFA updates, parallel data generation) and
//!   a scoped submit/join API ([`pool::ThreadPool::scope`]) whose jobs
//!   may borrow the caller's stack — the projector farm's shard
//!   closures and the row-block-parallel matmuls run through it.
//! * [`CancelToken`] — cooperative cancellation shared across workers.

pub mod oneshot;
pub mod pool;
pub mod queue;

pub use pool::{Scope, ThreadPool};

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, OnceLock};

/// Worker threads this host can usefully run (≥ 1).
pub fn host_cores() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Process-wide shared pool, sized to the host, built on first use.
/// For components that want parallelism without each spawning their own
/// workers (e.g. every digital trainer's pooled matmuls).  Lives for
/// the process; per-component pools (with their own metrics registry)
/// remain available via [`ThreadPool::with_registry`].
pub fn shared_pool() -> Arc<ThreadPool> {
    static POOL: OnceLock<Arc<ThreadPool>> = OnceLock::new();
    POOL.get_or_init(|| {
        let cores = host_cores();
        Arc::new(ThreadPool::new(cores, 4 * cores))
    })
    .clone()
}

/// Cooperative cancellation flag.
#[derive(Clone, Default)]
pub struct CancelToken {
    flag: Arc<AtomicBool>,
}

impl CancelToken {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn cancel(&self) {
        self.flag.store(true, Ordering::SeqCst);
    }

    pub fn is_cancelled(&self) -> bool {
        self.flag.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cancel_token_propagates() {
        let t = CancelToken::new();
        let t2 = t.clone();
        assert!(!t2.is_cancelled());
        t.cancel();
        assert!(t2.is_cancelled());
    }
}
