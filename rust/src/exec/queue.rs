//! Bounded MPMC blocking queue with close semantics, plus the
//! multi-lane variant ([`Lanes`]) used by the shard-aware projection
//! service.
//!
//! Mutex + two condvars; `push` blocks when full (backpressure — the OPU
//! frame clock is the slow consumer by design), `pop` blocks when empty,
//! and `close()` wakes everyone so shutdown is prompt.  Every lock and
//! condvar wait is poison-tolerant (`unwrap_or_else
//! (PoisonError::into_inner)`): the guarded state is a plain
//! `VecDeque + bool` with no invariant a mid-update panic can break,
//! and one panicking client must never wedge the queue for every other
//! producer and consumer sharing it.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::time::Duration;

use crate::metrics::trace::{self, NO_FRAME, NO_SHARD, NO_TOKEN};

struct Inner<T> {
    queue: Mutex<State<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
}

struct State<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Error returned when pushing to a closed queue.
#[derive(Debug, PartialEq, Eq)]
pub struct Closed;

/// Non-blocking push failure, returning the rejected item.
pub enum TryPushError<T> {
    Full(T),
    Closed(T),
}

pub struct BoundedQueue<T> {
    inner: Arc<Inner<T>>,
}

// Manual Clone: a queue handle is clonable regardless of T.
impl<T> Clone for BoundedQueue<T> {
    fn clone(&self) -> Self {
        BoundedQueue {
            inner: self.inner.clone(),
        }
    }
}

impl<T> BoundedQueue<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        BoundedQueue {
            inner: Arc::new(Inner {
                queue: Mutex::new(State {
                    items: VecDeque::new(),
                    closed: false,
                }),
                not_full: Condvar::new(),
                not_empty: Condvar::new(),
                capacity,
            }),
        }
    }

    /// Blocking push; returns `Err(Closed)` if the queue is closed.
    ///
    /// A push that actually blocks records a `queue_push_wait` trace
    /// span (self-timed, opened on the first blocked iteration); the
    /// uncontended fast path does not touch the tracer at all.
    pub fn push(&self, item: T) -> Result<(), Closed> {
        let mut st = self.inner.queue.lock().unwrap_or_else(PoisonError::into_inner);
        let mut wait = NO_TOKEN;
        loop {
            if st.closed {
                trace::complete(trace::STAGE_QUEUE_PUSH_WAIT, NO_FRAME, NO_SHARD, wait);
                return Err(Closed);
            }
            if st.items.len() < self.inner.capacity {
                st.items.push_back(item);
                self.inner.not_empty.notify_one();
                trace::complete(trace::STAGE_QUEUE_PUSH_WAIT, NO_FRAME, NO_SHARD, wait);
                return Ok(());
            }
            if wait == NO_TOKEN {
                wait = trace::start();
            }
            st = self
                .inner
                .not_full
                .wait(st)
                .unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Blocking pop; `None` once closed AND drained.
    ///
    /// Like [`push`](BoundedQueue::push), a pop that blocks records a
    /// self-timed `queue_pop_wait` span.
    pub fn pop(&self) -> Option<T> {
        let mut st = self.inner.queue.lock().unwrap_or_else(PoisonError::into_inner);
        let mut wait = NO_TOKEN;
        loop {
            if let Some(item) = st.items.pop_front() {
                self.inner.not_full.notify_one();
                trace::complete(trace::STAGE_QUEUE_POP_WAIT, NO_FRAME, NO_SHARD, wait);
                return Some(item);
            }
            if st.closed {
                trace::complete(trace::STAGE_QUEUE_POP_WAIT, NO_FRAME, NO_SHARD, wait);
                return None;
            }
            if wait == NO_TOKEN {
                wait = trace::start();
            }
            st = self
                .inner
                .not_empty
                .wait(st)
                .unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Pop with timeout; `Ok(None)` on timeout, `Err(Closed)` when closed
    /// and drained.
    pub fn pop_timeout(&self, timeout: Duration) -> Result<Option<T>, Closed> {
        let deadline = std::time::Instant::now() + timeout;
        let mut st = self.inner.queue.lock().unwrap_or_else(PoisonError::into_inner);
        loop {
            if let Some(item) = st.items.pop_front() {
                self.inner.not_full.notify_one();
                return Ok(Some(item));
            }
            if st.closed {
                return Err(Closed);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Ok(None);
            }
            let (new_st, res) = self
                .inner
                .not_empty
                .wait_timeout(st, deadline - now)
                .unwrap_or_else(PoisonError::into_inner);
            st = new_st;
            if res.timed_out() && st.items.is_empty() {
                if st.closed {
                    return Err(Closed);
                }
                return Ok(None);
            }
        }
    }

    /// Non-blocking push; hands the item back on a full or closed
    /// queue so the caller can act (e.g. the thread pool runs a queued
    /// job itself instead of blocking — nested-scope deadlock freedom).
    pub fn try_push(&self, item: T) -> Result<(), TryPushError<T>> {
        let mut st = self.inner.queue.lock().unwrap_or_else(PoisonError::into_inner);
        if st.closed {
            return Err(TryPushError::Closed(item));
        }
        if st.items.len() < self.inner.capacity {
            st.items.push_back(item);
            self.inner.not_empty.notify_one();
            Ok(())
        } else {
            Err(TryPushError::Full(item))
        }
    }

    /// Non-blocking pop.
    pub fn try_pop(&self) -> Option<T> {
        let mut st = self.inner.queue.lock().unwrap_or_else(PoisonError::into_inner);
        let item = st.items.pop_front();
        if item.is_some() {
            self.inner.not_full.notify_one();
        }
        item
    }

    /// Drain everything currently queued (non-blocking).
    pub fn drain(&self) -> Vec<T> {
        let mut st = self.inner.queue.lock().unwrap_or_else(PoisonError::into_inner);
        let out: Vec<T> = st.items.drain(..).collect();
        if !out.is_empty() {
            self.inner.not_full.notify_all();
        }
        out
    }

    pub fn len(&self) -> usize {
        let st = self.inner.queue.lock().unwrap_or_else(PoisonError::into_inner);
        st.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close: future pushes fail, pops drain then return None.
    pub fn close(&self) {
        let mut st = self.inner.queue.lock().unwrap_or_else(PoisonError::into_inner);
        st.closed = true;
        self.inner.not_empty.notify_all();
        self.inner.not_full.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        let st = self.inner.queue.lock().unwrap_or_else(PoisonError::into_inner);
        st.closed
    }
}

/// A fixed set of bounded MPMC lanes — one per shard.  Each lane is an
/// independent [`BoundedQueue`], so a slow shard exerts backpressure on
/// its own lane without stalling its siblings, while `close_all` makes
/// shutdown prompt across every lane.  Lane indices are stable: the
/// shard-aware projection service maps lane `i` to shard device `i`.
pub struct Lanes<T> {
    lanes: Vec<BoundedQueue<T>>,
}

// Manual Clone: a lane-set handle is clonable regardless of T.
impl<T> Clone for Lanes<T> {
    fn clone(&self) -> Self {
        Lanes {
            lanes: self.lanes.clone(),
        }
    }
}

impl<T> Lanes<T> {
    /// `count` lanes of `capacity` items each.
    pub fn new(count: usize, capacity: usize) -> Self {
        assert!(count > 0);
        Lanes {
            lanes: (0..count).map(|_| BoundedQueue::new(capacity)).collect(),
        }
    }

    pub fn count(&self) -> usize {
        self.lanes.len()
    }

    /// Blocking push into one lane; `Err(Closed)` after `close_all`.
    pub fn push(&self, lane: usize, item: T) -> Result<(), Closed> {
        self.lanes[lane].push(item)
    }

    /// Blocking pop from one lane; `None` once closed AND drained.
    pub fn pop(&self, lane: usize) -> Option<T> {
        self.lanes[lane].pop()
    }

    /// Non-blocking pop from one lane (the failover drain: the lane's
    /// worker may be consuming concurrently — each item still goes to
    /// exactly one consumer).
    pub fn try_pop(&self, lane: usize) -> Option<T> {
        self.lanes[lane].try_pop()
    }

    /// Items currently queued in one lane.
    pub fn len(&self, lane: usize) -> usize {
        self.lanes[lane].len()
    }

    /// True when every lane is empty.
    pub fn is_empty(&self) -> bool {
        self.lanes.iter().all(|l| l.is_empty())
    }

    /// Close every lane: pushes fail, pops drain then return `None`.
    pub fn close_all(&self) {
        for lane in &self.lanes {
            lane.close();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn fifo_order() {
        let q = BoundedQueue::new(10);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        for i in 0..5 {
            assert_eq!(q.pop(), Some(i));
        }
    }

    #[test]
    fn backpressure_blocks_until_pop() {
        let q = BoundedQueue::new(2);
        q.push(1).unwrap();
        q.push(2).unwrap();
        let q2 = q.clone();
        let handle = thread::spawn(move || {
            q2.push(3).unwrap(); // blocks until a pop
            3
        });
        thread::sleep(Duration::from_millis(50));
        assert_eq!(q.len(), 2); // still blocked
        assert_eq!(q.pop(), Some(1));
        assert_eq!(handle.join().unwrap(), 3);
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
    }

    #[test]
    fn try_push_hands_back_on_full_and_closed() {
        let q = BoundedQueue::new(1);
        assert!(q.try_push(1).is_ok());
        match q.try_push(2) {
            Err(TryPushError::Full(v)) => assert_eq!(v, 2),
            _ => panic!("expected Full"),
        }
        q.close();
        match q.try_push(3) {
            Err(TryPushError::Closed(v)) => assert_eq!(v, 3),
            _ => panic!("expected Closed"),
        }
        assert_eq!(q.pop(), Some(1));
    }

    #[test]
    fn close_wakes_consumers() {
        let q: BoundedQueue<u32> = BoundedQueue::new(4);
        let q2 = q.clone();
        let handle = thread::spawn(move || q2.pop());
        thread::sleep(Duration::from_millis(20));
        q.close();
        assert_eq!(handle.join().unwrap(), None);
        assert_eq!(q.push(1), Err(Closed));
    }

    #[test]
    fn close_drains_before_none() {
        let q = BoundedQueue::new(4);
        q.push(7).unwrap();
        q.close();
        assert_eq!(q.pop(), Some(7));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn pop_timeout_times_out() {
        let q: BoundedQueue<u32> = BoundedQueue::new(1);
        let t0 = std::time::Instant::now();
        assert_eq!(q.pop_timeout(Duration::from_millis(30)), Ok(None));
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn close_while_push_blocked_unblocks_with_closed() {
        // Shutdown-while-blocked: a producer stuck in backpressure must
        // be released by close(), not left waiting forever.
        let q = BoundedQueue::new(1);
        q.push(1).unwrap();
        let q2 = q.clone();
        let handle = thread::spawn(move || q2.push(2));
        thread::sleep(Duration::from_millis(30));
        assert_eq!(q.len(), 1, "producer should still be blocked");
        q.close();
        assert_eq!(handle.join().unwrap(), Err(Closed));
        // The item that was in flight before close still drains.
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn lanes_are_fifo_and_independent() {
        let lanes: Lanes<u32> = Lanes::new(3, 4);
        assert_eq!(lanes.count(), 3);
        // Interleaved pushes across lanes keep per-lane FIFO order.
        for i in 0..4u32 {
            for lane in 0..3 {
                lanes.push(lane, 10 * lane as u32 + i).unwrap();
            }
        }
        for lane in 0..3 {
            assert_eq!(lanes.len(lane), 4);
            for i in 0..4u32 {
                assert_eq!(lanes.pop(lane), Some(10 * lane as u32 + i));
            }
        }
        assert!(lanes.is_empty());
    }

    #[test]
    fn lane_backpressure_is_per_lane() {
        let lanes: Lanes<u32> = Lanes::new(2, 2);
        lanes.push(0, 1).unwrap();
        lanes.push(0, 2).unwrap();
        let l2 = lanes.clone();
        let handle = thread::spawn(move || {
            l2.push(0, 3).unwrap(); // lane 0 full: blocks
            3
        });
        thread::sleep(Duration::from_millis(30));
        assert_eq!(lanes.len(0), 2, "lane 0 producer should be blocked");
        // Lane 1 is unaffected by lane 0's backpressure.
        lanes.push(1, 7).unwrap();
        assert_eq!(lanes.pop(1), Some(7));
        // Draining lane 0 releases the blocked producer.
        assert_eq!(lanes.pop(0), Some(1));
        assert_eq!(handle.join().unwrap(), 3);
        assert_eq!(lanes.pop(0), Some(2));
        assert_eq!(lanes.pop(0), Some(3));
    }

    #[test]
    fn lanes_close_all_drains_then_ends() {
        let lanes: Lanes<u32> = Lanes::new(2, 4);
        lanes.push(0, 1).unwrap();
        lanes.push(1, 2).unwrap();
        let l2 = lanes.clone();
        let blocked = thread::spawn(move || l2.pop(0));
        thread::sleep(Duration::from_millis(20));
        lanes.close_all();
        // The blocked consumer gets the queued item; later pops get None.
        assert_eq!(blocked.join().unwrap(), Some(1));
        assert_eq!(lanes.pop(0), None);
        assert_eq!(lanes.pop(1), Some(2));
        assert_eq!(lanes.pop(1), None);
        assert_eq!(lanes.push(0, 9), Err(Closed));
    }

    #[test]
    fn lanes_close_while_push_blocked() {
        let lanes: Lanes<u32> = Lanes::new(2, 1);
        lanes.push(1, 1).unwrap();
        let l2 = lanes.clone();
        let handle = thread::spawn(move || l2.push(1, 2));
        thread::sleep(Duration::from_millis(30));
        lanes.close_all();
        assert_eq!(handle.join().unwrap(), Err(Closed));
    }

    #[test]
    fn poisoned_queue_keeps_serving() {
        // A consumer that panics while holding the queue lock poisons
        // the mutex; pushes and pops from other threads must carry on.
        let q: BoundedQueue<u32> = BoundedQueue::new(4);
        q.push(1).unwrap();
        let q2 = q.clone();
        let _ = thread::spawn(move || {
            let _guard = q2.inner.queue.lock().unwrap();
            panic!("poison the queue");
        })
        .join();
        q.push(2).unwrap();
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.len(), 0);
        q.close();
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn mpmc_no_loss_no_dup() {
        let q = BoundedQueue::new(8);
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let q = q.clone();
                thread::spawn(move || {
                    for i in 0..100u32 {
                        q.push(p * 100 + i).unwrap();
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let q = q.clone();
                thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(x) = q.pop() {
                        got.push(x);
                    }
                    got
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut all: Vec<u32> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort();
        let want: Vec<u32> = (0..4).flat_map(|p| (0..100).map(move |i| p * 100 + i)).collect();
        assert_eq!(all, want);
    }
}
