//! E4 — Perspectives scaling: "switching from off-axis to phase-shifting
//! holography will scale input and output size up to 1e6, and perform
//! calculations involving more than a trillion parameters".
//!
//! Two parts:
//! 1. The holography-scheme envelope table (off-axis vs phase-shifting):
//!    max dims, frames per projection, effective parameter count and
//!    MAC/s — the paper's scaling argument in numbers.
//! 2. A demonstration that the simulator honours the OPU's *memory-less*
//!    property: projections at 1e5 output modes via streamed
//!    transmission-matrix rows (the dense matrix would be 10^5×10^5×8 B =
//!    80 GB — never materialized; RSS stays flat).

use litl::bench::{fmt_rate, fmt_s, Bench};
use litl::optics::medium::TransmissionMatrix;
use litl::sim::power::{Holography, OpuModel};

fn main() -> anyhow::Result<()> {
    litl::util::logging::init();

    println!("== E4.1: holography-scheme envelope (paper Perspectives) ==");
    println!(
        "{:<16} {:>10} {:>10} {:>12} {:>16} {:>14}",
        "scheme", "max in", "max out", "proj/s", "params/frame", "eff. MAC/s"
    );
    for (name, scheme) in [
        ("off-axis", Holography::OffAxis),
        ("phase-shifting", Holography::PhaseShifting),
    ] {
        let m = OpuModel::paper(scheme);
        let params = m.max_input as f64 * m.max_output as f64;
        println!(
            "{:<16} {:>10} {:>10} {:>12} {:>16} {:>14}",
            name,
            m.max_input,
            m.max_output,
            format!("{:.0}", m.frame_rate_hz),
            format!("{:.1e}", params),
            fmt_rate(m.effective_macs(m.max_input, m.max_output).unwrap()),
        );
    }
    let ps = OpuModel::paper(Holography::PhaseShifting);
    let params = ps.max_input as f64 * ps.max_output as f64;
    println!(
        "\npaper: 'more than a trillion parameters' → model: {params:.1e} {}",
        if params >= 1e12 { "(HOLDS)" } else { "(DIVERGES)" }
    );

    // ---- E4.2: memory-less projection at paper scale ----
    println!("\n== E4.2: streamed (memory-less) projection ==");
    let mut bench = Bench::quick();
    let d_in = 100usize; // active SLM pixels (ternary error, nnz ≤ d_in)
    println!(
        "{:>10} {:>14} {:>16} {:>14}",
        "d_out", "sim wallclock", "dense B bytes", "allocated"
    );
    for modes in [10_000usize, 100_000] {
        let e: Vec<f32> = (0..d_in)
            .map(|i| match i % 3 {
                0 => 1.0,
                1 => -1.0,
                _ => 0.0,
            })
            .collect();
        let mut out_norm = 0.0f64;
        let m = bench.run(&format!("streamed d_out={modes}"), || {
            let (re, _im) = TransmissionMatrix::project_streamed(9, &e, modes);
            out_norm = re.iter().map(|&x| (x as f64).powi(2)).sum();
        });
        let dense_bytes = (d_in * modes * 8) as f64;
        println!(
            "{:>10} {:>14} {:>16} {:>14}",
            modes,
            fmt_s(m.mean_s),
            format!("{:.1} MB", dense_bytes / 1e6),
            format!("{:.1} MB", (2 * modes * 4) as f64 / 1e6),
        );
        assert!(out_norm.is_finite() && out_norm > 0.0);
    }
    println!(
        "\nthe physical device pays ZERO of this cost — light does the matmul;\n\
         the frame clock (1/1500 s) is the only time axis.  The sim cost above\n\
         is what this sandbox pays to *emulate* the optics numerically."
    );

    // Sanity: projection statistics hold at scale (unit-variance modes).
    let e: Vec<f32> = (0..d_in)
        .map(|i| if i % 2 == 0 { 1.0 } else { -1.0 })
        .collect();
    let nnz = e.iter().filter(|&&x| x != 0.0).count() as f64;
    let (re, im) = TransmissionMatrix::project_streamed(11, &e, 100_000);
    let var_re: f64 =
        re.iter().map(|&x| (x as f64).powi(2)).sum::<f64>() / re.len() as f64;
    let var_im: f64 =
        im.iter().map(|&x| (x as f64).powi(2)).sum::<f64>() / im.len() as f64;
    println!(
        "\nprojection variance at d_out=1e5: re={:.3} im={:.3} (theory nnz/2 = {:.3})",
        var_re,
        var_im,
        nnz / 2.0
    );
    assert!((var_re - nnz / 2.0).abs() < 0.05 * nnz);
    assert!((var_im - nnz / 2.0).abs() < 0.05 * nnz);
    println!("variance check: OK");
    Ok(())
}
