//! E4 — Perspectives scaling: "switching from off-axis to phase-shifting
//! holography will scale input and output size up to 1e6, and perform
//! calculations involving more than a trillion parameters".
//!
//! Two parts:
//! 1. The holography-scheme envelope table (off-axis vs phase-shifting):
//!    max dims, frames per projection, effective parameter count and
//!    MAC/s — the paper's scaling argument in numbers.
//! 2. A demonstration that the simulator honours the OPU's *memory-less*
//!    property: projections at 1e5 output modes via streamed
//!    transmission-matrix rows (the dense matrix would be 10^5×10^5×8 B =
//!    80 GB — never materialized; RSS stays flat).

use std::collections::BTreeMap;

use litl::bench::{fmt_rate, fmt_s, Bench};
use litl::coordinator::farm::ProjectorFarm;
use litl::coordinator::projector::Projector;
use litl::optics::medium::TransmissionMatrix;
use litl::optics::OpuParams;
use litl::sim::power::{Holography, OpuModel};
use litl::tensor::Tensor;
use litl::util::json::Json;
use litl::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    litl::util::logging::init();

    println!("== E4.1: holography-scheme envelope (paper Perspectives) ==");
    println!(
        "{:<16} {:>10} {:>10} {:>12} {:>16} {:>14}",
        "scheme", "max in", "max out", "proj/s", "params/frame", "eff. MAC/s"
    );
    for (name, scheme) in [
        ("off-axis", Holography::OffAxis),
        ("phase-shifting", Holography::PhaseShifting),
    ] {
        let m = OpuModel::paper(scheme);
        let params = m.max_input as f64 * m.max_output as f64;
        println!(
            "{:<16} {:>10} {:>10} {:>12} {:>16} {:>14}",
            name,
            m.max_input,
            m.max_output,
            format!("{:.0}", m.frame_rate_hz),
            format!("{:.1e}", params),
            fmt_rate(m.effective_macs(m.max_input, m.max_output).unwrap()),
        );
    }
    let ps = OpuModel::paper(Holography::PhaseShifting);
    let params = ps.max_input as f64 * ps.max_output as f64;
    println!(
        "\npaper: 'more than a trillion parameters' → model: {params:.1e} {}",
        if params >= 1e12 { "(HOLDS)" } else { "(DIVERGES)" }
    );

    // ---- E4.2: memory-less projection at paper scale ----
    println!("\n== E4.2: streamed (memory-less) projection ==");
    let mut bench = Bench::quick();
    let d_in = 100usize; // active SLM pixels (ternary error, nnz ≤ d_in)
    println!(
        "{:>10} {:>14} {:>16} {:>14}",
        "d_out", "sim wallclock", "dense B bytes", "allocated"
    );
    for modes in [10_000usize, 100_000] {
        let e: Vec<f32> = (0..d_in)
            .map(|i| match i % 3 {
                0 => 1.0,
                1 => -1.0,
                _ => 0.0,
            })
            .collect();
        let mut out_norm = 0.0f64;
        let m = bench.run(&format!("streamed d_out={modes}"), || {
            let (re, _im) = TransmissionMatrix::project_streamed(9, &e, modes);
            out_norm = re.iter().map(|&x| (x as f64).powi(2)).sum();
        });
        let dense_bytes = (d_in * modes * 8) as f64;
        println!(
            "{:>10} {:>14} {:>16} {:>14}",
            modes,
            fmt_s(m.mean_s),
            format!("{:.1} MB", dense_bytes / 1e6),
            format!("{:.1} MB", (2 * modes * 4) as f64 / 1e6),
        );
        assert!(out_norm.is_finite() && out_norm > 0.0);
    }
    println!(
        "\nthe physical device pays ZERO of this cost — light does the matmul;\n\
         the frame clock (1/1500 s) is the only time axis.  The sim cost above\n\
         is what this sandbox pays to *emulate* the optics numerically."
    );

    // ---- E4.3: projector-farm shard sweep ----
    //
    // The multi-device direction of the follow-up work: shard the output
    // modes of one projection across N virtual OPUs and run the shards
    // concurrently.  Measured wall-clock here is the *simulation* cost of
    // the optics; the physical farm's wall clock stays one frame period
    // while capacity scales (see `OpuModel::farm`, printed below).
    println!("\n== E4.3: projector-farm shard sweep (measured, this host) ==");
    let cores = litl::exec::host_cores();
    let (farm_d_in, farm_modes, batch) = (10usize, 2048usize, 32usize);
    println!(
        "host cores: {cores} | d_in={farm_d_in} modes={farm_modes} batch={batch} \
         (optical physics sim)"
    );
    println!(
        "{:>8} {:>12} {:>14} {:>10} {:>16}",
        "shards", "mean/batch", "frames/s", "speedup", "dev-s/batch"
    );
    let medium = TransmissionMatrix::sample(21, farm_d_in, farm_modes);
    let mut rng = Pcg64::seeded(4);
    let mut e = Tensor::zeros(&[batch, farm_d_in]);
    for v in e.data_mut() {
        *v = (rng.next_below(3) as i64 - 1) as f32;
    }
    let mut sweep = Bench::quick();
    let mut baseline_mean = 0.0f64;
    let mut rows: Vec<Json> = Vec::new();
    for shards in [1usize, 2, 4, 8] {
        let mut farm = ProjectorFarm::optical(OpuParams::default(), &medium, 9, shards)?;
        // Per-batch device-seconds from the first (warm-up) batch: the
        // accumulator after the bench would include a budget-dependent
        // iteration count and not be comparable across rows.
        farm.project(&e)?;
        let dev_s_batch = farm.sim_seconds();
        let m = sweep.run(&format!("farm shards={shards}"), || {
            let _ = farm.project(&e).unwrap();
        });
        if shards == 1 {
            baseline_mean = m.mean_s;
        }
        let speedup = baseline_mean / m.mean_s;
        let frames_per_s = batch as f64 / m.mean_s;
        println!(
            "{:>8} {:>12} {:>14} {:>10} {:>16}",
            shards,
            fmt_s(m.mean_s),
            fmt_rate(frames_per_s),
            format!("{speedup:.2}x"),
            format!("{dev_s_batch:.4} s"),
        );
        let mut row = BTreeMap::new();
        row.insert("shards".to_string(), Json::Num(shards as f64));
        row.insert("mean_s".to_string(), Json::Num(m.mean_s));
        row.insert("frames_per_s".to_string(), Json::Num(frames_per_s));
        row.insert("speedup_vs_1".to_string(), Json::Num(speedup));
        row.insert(
            "sim_device_seconds_per_batch".to_string(),
            Json::Num(dev_s_batch),
        );
        rows.push(Json::Obj(row));
    }
    // Machine-readable record in the bench JSON format (one object/line).
    let mut record = BTreeMap::new();
    record.insert("bench".to_string(), Json::Str("e4_shard_sweep".to_string()));
    record.insert("modes".to_string(), Json::Num(farm_modes as f64));
    record.insert("batch".to_string(), Json::Num(batch as f64));
    record.insert("d_in".to_string(), Json::Num(farm_d_in as f64));
    record.insert("host_cores".to_string(), Json::Num(cores as f64));
    record.insert("results".to_string(), Json::Arr(rows));
    println!("{}", Json::Obj(record).to_string_compact());

    // Physical-farm envelope: same frame clock, N× capacity and power.
    println!("\nmodeled physical farm (off-axis paper device × N):");
    println!(
        "{:>8} {:>12} {:>14} {:>14}",
        "devices", "proj/s", "max out", "eff. MAC/s"
    );
    let base = OpuModel::paper(Holography::OffAxis);
    for n in [1usize, 2, 4, 8] {
        let farm = base.farm(n);
        println!(
            "{:>8} {:>12} {:>14} {:>14}",
            n,
            format!("{:.0}", farm.frame_rate_hz),
            farm.max_output,
            fmt_rate(farm.effective_macs(base.max_input, farm.max_output).unwrap()),
        );
    }

    // Sanity: projection statistics hold at scale (unit-variance modes).
    let e: Vec<f32> = (0..d_in)
        .map(|i| if i % 2 == 0 { 1.0 } else { -1.0 })
        .collect();
    let nnz = e.iter().filter(|&&x| x != 0.0).count() as f64;
    let (re, im) = TransmissionMatrix::project_streamed(11, &e, 100_000);
    let var_re: f64 =
        re.iter().map(|&x| (x as f64).powi(2)).sum::<f64>() / re.len() as f64;
    let var_im: f64 =
        im.iter().map(|&x| (x as f64).powi(2)).sum::<f64>() / im.len() as f64;
    println!(
        "\nprojection variance at d_out=1e5: re={:.3} im={:.3} (theory nnz/2 = {:.3})",
        var_re,
        var_im,
        nnz / 2.0
    );
    assert!((var_re - nnz / 2.0).abs() < 0.05 * nnz);
    assert!((var_im - nnz / 2.0).abs() < 0.05 * nnz);
    println!("variance check: OK");
    Ok(())
}
