//! E4 — Perspectives scaling: "switching from off-axis to phase-shifting
//! holography will scale input and output size up to 1e6, and perform
//! calculations involving more than a trillion parameters".
//!
//! Two parts:
//! 1. The holography-scheme envelope table (off-axis vs phase-shifting):
//!    max dims, frames per projection, effective parameter count and
//!    MAC/s — the paper's scaling argument in numbers.
//! 2. A demonstration that the simulator honours the OPU's *memory-less*
//!    property: projections at 1e5 output modes via streamed
//!    transmission-matrix rows (the dense matrix would be 10^5×10^5×8 B =
//!    80 GB — never materialized; RSS stays flat).

use std::collections::BTreeMap;

use litl::bench::{fmt_rate, fmt_s, Bench};
use litl::config::Partition;
use litl::coordinator::farm::ProjectorFarm;
use litl::coordinator::projector::{DigitalProjector, NativeOpticalProjector, Projector};
use litl::coordinator::service::{
    ProjectionService, ServiceConfig, ShardServiceConfig, ShardedProjectionService,
};
use litl::coordinator::topology::{DeviceKind, Topology};
use litl::coordinator::ProjectionClient;
use litl::metrics::Registry;
use litl::optics::medium::TransmissionMatrix;
use litl::optics::stream::Medium;
use litl::optics::OpuParams;
use litl::sim::power::{Holography, OpuModel};
use litl::tensor::Tensor;
use litl::util::json::Json;
use litl::util::rng::Pcg64;

/// A shard device with a simulated *service-rate handicap*: sleeps
/// `us_per_row` microseconds per row before projecting.  Stands in for
/// the heterogeneous-fleet reality (older cameras, degraded links)
/// that weighted scheduling is for.
struct Throttled {
    inner: Box<dyn Projector + Send>,
    us_per_row: u64,
}

impl Projector for Throttled {
    fn project(&mut self, frames: &Tensor) -> anyhow::Result<(Tensor, Tensor)> {
        std::thread::sleep(std::time::Duration::from_micros(
            self.us_per_row * frames.rows() as u64,
        ));
        self.inner.project(frames)
    }

    fn modes(&self) -> usize {
        self.inner.modes()
    }

    fn sim_seconds(&self) -> f64 {
        self.inner.sim_seconds()
    }

    fn energy_joules(&self) -> f64 {
        self.inner.energy_joules()
    }

    fn kind(&self) -> &'static str {
        "throttled"
    }

    fn requires_ternary(&self) -> bool {
        self.inner.requires_ternary()
    }
}

/// Drive `clients` threads, each submitting `submissions` requests of
/// `rows` ternary frames through its own client handle, waiting for
/// every reply; returns the wall-clock seconds for the whole workload.
fn run_service_workload(
    client: &ProjectionClient,
    clients: usize,
    submissions: usize,
    rows: usize,
    d_in: usize,
) -> f64 {
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let client = client.clone();
            std::thread::spawn(move || {
                let mut rng = Pcg64::seeded(9000 + c as u64);
                for _ in 0..submissions {
                    let mut e = Tensor::zeros(&[rows, d_in]);
                    for v in e.data_mut() {
                        *v = (rng.next_below(3) as i64 - 1) as f32;
                    }
                    client.project(e).unwrap();
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    t0.elapsed().as_secs_f64()
}

fn main() -> anyhow::Result<()> {
    litl::util::logging::init();

    println!("== E4.1: holography-scheme envelope (paper Perspectives) ==");
    println!(
        "{:<16} {:>10} {:>10} {:>12} {:>16} {:>14}",
        "scheme", "max in", "max out", "proj/s", "params/frame", "eff. MAC/s"
    );
    for (name, scheme) in [
        ("off-axis", Holography::OffAxis),
        ("phase-shifting", Holography::PhaseShifting),
    ] {
        let m = OpuModel::paper(scheme);
        let params = m.max_input as f64 * m.max_output as f64;
        println!(
            "{:<16} {:>10} {:>10} {:>12} {:>16} {:>14}",
            name,
            m.max_input,
            m.max_output,
            format!("{:.0}", m.frame_rate_hz),
            format!("{:.1e}", params),
            fmt_rate(m.effective_macs(m.max_input, m.max_output).unwrap()),
        );
    }
    let ps = OpuModel::paper(Holography::PhaseShifting);
    let params = ps.max_input as f64 * ps.max_output as f64;
    println!(
        "\npaper: 'more than a trillion parameters' → model: {params:.1e} {}",
        if params >= 1e12 { "(HOLDS)" } else { "(DIVERGES)" }
    );

    // ---- E4.2: memory-less projection at paper scale ----
    println!("\n== E4.2: streamed (memory-less) projection ==");
    let mut bench = Bench::quick();
    let d_in = 100usize; // active SLM pixels (ternary error, nnz ≤ d_in)
    println!(
        "{:>10} {:>14} {:>16} {:>14}",
        "d_out", "sim wallclock", "dense B bytes", "allocated"
    );
    for modes in [10_000usize, 100_000] {
        let e: Vec<f32> = (0..d_in)
            .map(|i| match i % 3 {
                0 => 1.0,
                1 => -1.0,
                _ => 0.0,
            })
            .collect();
        let mut out_norm = 0.0f64;
        let m = bench.run(&format!("streamed d_out={modes}"), || {
            let (re, _im) = TransmissionMatrix::project_streamed(9, &e, modes);
            out_norm = re.iter().map(|&x| (x as f64).powi(2)).sum();
        });
        let dense_bytes = (d_in * modes * 8) as f64;
        println!(
            "{:>10} {:>14} {:>16} {:>14}",
            modes,
            fmt_s(m.mean_s),
            format!("{:.1} MB", dense_bytes / 1e6),
            format!("{:.1} MB", (2 * modes * 4) as f64 / 1e6),
        );
        assert!(out_norm.is_finite() && out_norm > 0.0);
    }
    println!(
        "\nthe physical device pays ZERO of this cost — light does the matmul;\n\
         the frame clock (1/1500 s) is the only time axis.  The sim cost above\n\
         is what this sandbox pays to *emulate* the optics numerically."
    );

    // ---- E4.3: projector-farm shard sweep ----
    //
    // The multi-device direction of the follow-up work: shard the output
    // modes of one projection across N virtual OPUs and run the shards
    // concurrently.  Measured wall-clock here is the *simulation* cost of
    // the optics; the physical farm's wall clock stays one frame period
    // while capacity scales (see `OpuModel::farm`, printed below).
    println!("\n== E4.3: projector-farm shard sweep (measured, this host) ==");
    let cores = litl::exec::host_cores();
    let (farm_d_in, farm_modes, batch) = (10usize, 2048usize, 32usize);
    println!(
        "host cores: {cores} | d_in={farm_d_in} modes={farm_modes} batch={batch} \
         (optical physics sim)"
    );
    println!(
        "{:>8} {:>12} {:>14} {:>10} {:>16}",
        "shards", "mean/batch", "frames/s", "speedup", "dev-s/batch"
    );
    let medium = TransmissionMatrix::sample(21, farm_d_in, farm_modes);
    let mut rng = Pcg64::seeded(4);
    let mut e = Tensor::zeros(&[batch, farm_d_in]);
    for v in e.data_mut() {
        *v = (rng.next_below(3) as i64 - 1) as f32;
    }
    let mut sweep = Bench::quick();
    let mut baseline_mean = 0.0f64;
    let mut rows: Vec<Json> = Vec::new();
    for shards in [1usize, 2, 4, 8] {
        let mut farm = Topology::homogeneous(DeviceKind::Optical, shards).build_farm(
            OpuParams::default(),
            &Medium::Dense(medium.clone()),
            9,
            Registry::new(),
        )?;
        // Per-batch device-seconds from the first (warm-up) batch: the
        // accumulator after the bench would include a budget-dependent
        // iteration count and not be comparable across rows.
        farm.project(&e)?;
        let dev_s_batch = farm.sim_seconds();
        let m = sweep.run(&format!("farm shards={shards}"), || {
            let _ = farm.project(&e).unwrap();
        });
        if shards == 1 {
            baseline_mean = m.mean_s;
        }
        let speedup = baseline_mean / m.mean_s;
        let frames_per_s = batch as f64 / m.mean_s;
        println!(
            "{:>8} {:>12} {:>14} {:>10} {:>16}",
            shards,
            fmt_s(m.mean_s),
            fmt_rate(frames_per_s),
            format!("{speedup:.2}x"),
            format!("{dev_s_batch:.4} s"),
        );
        let mut row = BTreeMap::new();
        row.insert("shards".to_string(), Json::Num(shards as f64));
        row.insert("mean_s".to_string(), Json::Num(m.mean_s));
        row.insert("frames_per_s".to_string(), Json::Num(frames_per_s));
        row.insert("speedup_vs_1".to_string(), Json::Num(speedup));
        row.insert(
            "sim_device_seconds_per_batch".to_string(),
            Json::Num(dev_s_batch),
        );
        rows.push(Json::Obj(row));
    }
    // Machine-readable record in the bench JSON format (one object/line).
    let mut record = BTreeMap::new();
    record.insert("bench".to_string(), Json::Str("e4_shard_sweep".to_string()));
    record.insert("modes".to_string(), Json::Num(farm_modes as f64));
    record.insert("batch".to_string(), Json::Num(batch as f64));
    record.insert("d_in".to_string(), Json::Num(farm_d_in as f64));
    record.insert("host_cores".to_string(), Json::Num(cores as f64));
    record.insert("results".to_string(), Json::Arr(rows));
    println!("{}", Json::Obj(record).to_string_compact());

    // ---- E4.4: shard-aware service sweep ----
    //
    // The serving question behind the farm: when many clients contend
    // for the optical device, does shard-aware scheduling (per-shard
    // lanes + frame-slot assignment) beat the device-agnostic service
    // (one dispatcher, one opaque device call per batch)?  Sweep
    // clients × shards × partition; "agnostic" rows are the baseline.
    println!("\n== E4.4: shard-aware service sweep (clients × shards × partition) ==");
    let (sv_d_in, sv_modes, sv_rows, sv_reqs) = (10usize, 1024usize, 8usize, 6usize);
    let sv_medium = TransmissionMatrix::sample(31, sv_d_in, sv_modes);
    println!(
        "d_in={sv_d_in} modes={sv_modes} rows/request={sv_rows} requests/client={sv_reqs}"
    );
    println!(
        "{:>8} {:>8} {:>10} {:>12} {:>14} {:>12}",
        "clients", "shards", "partition", "wall", "frames/s", "vs agnostic"
    );
    let mut service_rows: Vec<Json> = Vec::new();
    let mut speedup_4shard_multiclient = 0.0f64;
    for &clients in &[1usize, 4, 8] {
        let total_frames = (clients * sv_reqs * sv_rows) as f64;
        let base_svc = ProjectionService::start(
            Box::new(NativeOpticalProjector::new(
                OpuParams::default(),
                sv_medium.clone(),
                9,
            )),
            sv_d_in,
            ServiceConfig {
                max_batch: 64,
                queue_depth: 128,
            },
            Registry::new(),
        );
        let wall_base = {
            let c = base_svc.client();
            run_service_workload(&c, clients, sv_reqs, sv_rows, sv_d_in)
        };
        base_svc.shutdown();
        println!(
            "{:>8} {:>8} {:>10} {:>12} {:>14} {:>12}",
            clients,
            1,
            "agnostic",
            fmt_s(wall_base),
            fmt_rate(total_frames / wall_base),
            "1.00x"
        );
        let mut row = BTreeMap::new();
        row.insert("clients".to_string(), Json::Num(clients as f64));
        row.insert("shards".to_string(), Json::Num(1.0));
        row.insert("partition".to_string(), Json::Str("agnostic".to_string()));
        row.insert("wall_s".to_string(), Json::Num(wall_base));
        row.insert(
            "frames_per_s".to_string(),
            Json::Num(total_frames / wall_base),
        );
        row.insert("speedup_vs_agnostic".to_string(), Json::Num(1.0));
        service_rows.push(Json::Obj(row));
        for partition in [Partition::Modes, Partition::Batch] {
            for &shards in &[1usize, 2, 4] {
                let devices = Topology::homogeneous(DeviceKind::Optical, shards)
                    .with_partition(partition)
                    .build_devices(
                        OpuParams::default(),
                        &Medium::Dense(sv_medium.clone()),
                        9,
                        &Registry::new(),
                    )?;
                let svc = ShardedProjectionService::start(
                    devices,
                    sv_d_in,
                    ShardServiceConfig {
                        max_batch: 64,
                        queue_depth: 128,
                        lane_depth: 8,
                        partition,
                        ..Default::default()
                    },
                    Registry::new(),
                )?;
                let wall = {
                    let c = svc.client();
                    run_service_workload(&c, clients, sv_reqs, sv_rows, sv_d_in)
                };
                svc.shutdown();
                let speedup = wall_base / wall;
                if shards == 4 && clients > 1 {
                    speedup_4shard_multiclient = speedup_4shard_multiclient.max(speedup);
                }
                println!(
                    "{:>8} {:>8} {:>10} {:>12} {:>14} {:>12}",
                    clients,
                    shards,
                    partition.name(),
                    fmt_s(wall),
                    fmt_rate(total_frames / wall),
                    format!("{speedup:.2}x")
                );
                let mut row = BTreeMap::new();
                row.insert("clients".to_string(), Json::Num(clients as f64));
                row.insert("shards".to_string(), Json::Num(shards as f64));
                row.insert(
                    "partition".to_string(),
                    Json::Str(partition.name().to_string()),
                );
                row.insert("wall_s".to_string(), Json::Num(wall));
                row.insert(
                    "frames_per_s".to_string(),
                    Json::Num(total_frames / wall),
                );
                row.insert("speedup_vs_agnostic".to_string(), Json::Num(speedup));
                service_rows.push(Json::Obj(row));
            }
        }
    }
    let mut service_record = BTreeMap::new();
    service_record.insert(
        "bench".to_string(),
        Json::Str("e4_service_sweep".to_string()),
    );
    service_record.insert("modes".to_string(), Json::Num(sv_modes as f64));
    service_record.insert("d_in".to_string(), Json::Num(sv_d_in as f64));
    service_record.insert("rows_per_request".to_string(), Json::Num(sv_rows as f64));
    service_record.insert(
        "requests_per_client".to_string(),
        Json::Num(sv_reqs as f64),
    );
    service_record.insert("host_cores".to_string(), Json::Num(cores as f64));
    service_record.insert("results".to_string(), Json::Arr(service_rows));
    println!("{}", Json::Obj(service_record).to_string_compact());
    println!(
        "4-shard service vs device-agnostic (multi-client best): \
         {speedup_4shard_multiclient:.2}x {}",
        if speedup_4shard_multiclient > 1.5 {
            "(>1.5x target HOLDS)"
        } else {
            "(below 1.5x target on this host)"
        }
    );

    // ---- E4.5: weighted vs even row split on skewed device speeds ----
    //
    // The weighted frame-slot schedule's payoff, measured: a two-replica
    // batch-partition farm where one device services rows `skew`× slower
    // (a throttled digital replica).  The even split parks half the
    // batch on the slow device; weighting the fast device `skew:1`
    // shifts rows to match the service rates, so the critical path
    // (slowest shard) shrinks.
    println!("\n== E4.5: hetero sweep — weighted vs even row split ==");
    let (ht_d_in, ht_modes, ht_batch) = (10usize, 512usize, 64usize);
    let ht_medium = TransmissionMatrix::sample(71, ht_d_in, ht_modes);
    let mut ht_e = Tensor::zeros(&[ht_batch, ht_d_in]);
    let mut ht_rng = Pcg64::seeded(6);
    for v in ht_e.data_mut() {
        *v = (ht_rng.next_below(3) as i64 - 1) as f32;
    }
    println!(
        "{:>6} {:>10} {:>12} {:>14} {:>12}",
        "skew", "weights", "mean/batch", "frames/s", "vs even"
    );
    let mut hetero_rows: Vec<Json> = Vec::new();
    for &skew in &[2u64, 4] {
        let build = |weights: Vec<u32>| -> anyhow::Result<ProjectorFarm> {
            // Shard 0: full speed.  Shard 1: `skew`× slower per row.
            let slow_us = 40 * skew;
            let devices: Vec<Box<dyn Projector + Send>> = vec![
                Box::new(Throttled {
                    inner: Box::new(DigitalProjector::new(ht_medium.clone())),
                    us_per_row: 40,
                }),
                Box::new(Throttled {
                    inner: Box::new(DigitalProjector::new(ht_medium.clone())),
                    us_per_row: slow_us,
                }),
            ];
            ProjectorFarm::from_shards_weighted(
                devices,
                weights,
                "farm-hetero-bench",
                Partition::Batch,
                Registry::new(),
                None,
            )
        };
        let mut even_mean = 0.0f64;
        for (label, weights) in [
            ("even", vec![1u32, 1]),
            ("matched", vec![skew as u32, 1]),
        ] {
            let mut farm = build(weights.clone())?;
            farm.project(&ht_e)?; // warm-up
            let mut bench = Bench::quick();
            let m = bench.run(&format!("hetero skew={skew} {label}"), || {
                let _ = farm.project(&ht_e).unwrap();
            });
            if label == "even" {
                even_mean = m.mean_s;
            }
            let speedup = even_mean / m.mean_s;
            println!(
                "{:>6} {:>10} {:>12} {:>14} {:>12}",
                skew,
                format!("{}:{}", weights[0], weights[1]),
                fmt_s(m.mean_s),
                fmt_rate(ht_batch as f64 / m.mean_s),
                format!("{speedup:.2}x"),
            );
            let mut row = BTreeMap::new();
            row.insert("skew".to_string(), Json::Num(skew as f64));
            row.insert(
                "weights".to_string(),
                Json::Str(format!("{}:{}", weights[0], weights[1])),
            );
            row.insert("mean_s".to_string(), Json::Num(m.mean_s));
            row.insert(
                "frames_per_s".to_string(),
                Json::Num(ht_batch as f64 / m.mean_s),
            );
            row.insert("speedup_vs_even".to_string(), Json::Num(speedup));
            hetero_rows.push(Json::Obj(row));
        }
    }
    let mut hetero_record = BTreeMap::new();
    hetero_record.insert("bench".to_string(), Json::Str("e4_hetero_sweep".to_string()));
    hetero_record.insert("modes".to_string(), Json::Num(ht_modes as f64));
    hetero_record.insert("d_in".to_string(), Json::Num(ht_d_in as f64));
    hetero_record.insert("batch".to_string(), Json::Num(ht_batch as f64));
    hetero_record.insert("results".to_string(), Json::Arr(hetero_rows));
    println!("{}", Json::Obj(hetero_record).to_string_compact());

    // Physical-farm envelope: same frame clock, N× capacity and power.
    println!("\nmodeled physical farm (off-axis paper device × N):");
    println!(
        "{:>8} {:>12} {:>14} {:>14}",
        "devices", "proj/s", "max out", "eff. MAC/s"
    );
    let base = OpuModel::paper(Holography::OffAxis);
    for n in [1usize, 2, 4, 8] {
        let farm = base.farm(n);
        println!(
            "{:>8} {:>12} {:>14} {:>14}",
            n,
            format!("{:.0}", farm.frame_rate_hz),
            farm.max_output,
            fmt_rate(farm.effective_macs(base.max_input, farm.max_output).unwrap()),
        );
    }

    // Sanity: projection statistics hold at scale (unit-variance modes).
    let e: Vec<f32> = (0..d_in)
        .map(|i| if i % 2 == 0 { 1.0 } else { -1.0 })
        .collect();
    let nnz = e.iter().filter(|&&x| x != 0.0).count() as f64;
    let (re, im) = TransmissionMatrix::project_streamed(11, &e, 100_000);
    let var_re: f64 =
        re.iter().map(|&x| (x as f64).powi(2)).sum::<f64>() / re.len() as f64;
    let var_im: f64 =
        im.iter().map(|&x| (x as f64).powi(2)).sum::<f64>() / im.len() as f64;
    println!(
        "\nprojection variance at d_out=1e5: re={:.3} im={:.3} (theory nnz/2 = {:.3})",
        var_re,
        var_im,
        nnz / 2.0
    );
    assert!((var_re - nnz / 2.0).abs() < 0.05 * nnz);
    assert!((var_im - nnz / 2.0).abs() < 0.05 * nnz);
    println!("variance check: OK");
    Ok(())
}
