//! E3 — power efficiency: "~30 W" and "up to one order of magnitude more
//! power efficient" than GPUs at large scale.
//!
//! Reports joules per projection and projections per joule across the
//! output-dimension axis for the OPU model (paper constants), the V100
//! roofline (datasheet), and this host's measured CPU, in the paper's
//! operating regime (per-step DFA feedback, i.e. small effective batch).

use litl::bench::Bench;
use litl::optics::medium::TransmissionMatrix;
use litl::sim::power::{CpuModel, GpuModel, Holography, OpuModel};
use litl::tensor::{matmul, Tensor};
use litl::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    litl::util::logging::init();

    // Calibrate CPU MAC/s from a quick measurement.
    let mut bench = Bench::quick();
    let d_in = 10usize;
    let modes = 2048usize;
    let batch = 128usize;
    let medium = TransmissionMatrix::sample(1, d_in, modes);
    let mut rng = Pcg64::seeded(2);
    let mut e = Tensor::zeros(&[batch, d_in]);
    for v in e.data_mut() {
        *v = (rng.next_below(3) as i64 - 1) as f32;
    }
    let m = bench.run("cpu matmul calib", || {
        let _ = matmul(&e, &medium.b_re);
    });
    let cpu = CpuModel::measured((d_in * modes * batch) as f64 / m.mean_s);

    let opu = OpuModel::paper(Holography::OffAxis);
    let gpu = GpuModel::v100();
    let d_in_big = 1_000_000usize;

    println!("\n== E3: energy per projection (input dim 1e6, DFA feedback batch=1) ==");
    println!(
        "{:>10} {:>14} {:>14} {:>14} {:>12}",
        "d_out", "OPU J/proj", "GPU J/proj", "CPU J/proj", "GPU/OPU"
    );
    let mut headline_ratio = 0.0f64;
    for d_out in [1_000usize, 10_000, 50_000, 100_000] {
        let opu_j = opu.energy(1);
        let gpu_j = if gpu.supports(d_in_big, d_out) {
            Some(gpu.energy(d_in_big, d_out, 1, 1))
        } else {
            None
        };
        let cpu_j = cpu.seconds(d_in_big, d_out, 1) * cpu.power_watts;
        let ratio = gpu_j.map(|g| g / opu_j);
        if let Some(r) = ratio {
            headline_ratio = headline_ratio.max(r);
        }
        println!(
            "{:>10} {:>14.4} {:>14} {:>14.3} {:>12}",
            d_out,
            opu_j,
            gpu_j.map(|g| format!("{g:.4}")).unwrap_or("— (OOM)".into()),
            cpu_j,
            ratio.map(|r| format!("{r:.1}x")).unwrap_or("∞ (OOM)".into()),
        );
    }

    println!("\n== modeled device power ==");
    println!("  OPU: {:>6.0} W (paper §III: 'about 30 W')", opu.power_watts);
    println!("  GPU: {:>6.0} W (V100 TDP)", gpu.power_watts);
    println!("  CPU: {:>6.0} W (single-core package share)", cpu.power_watts);

    println!(
        "\npaper claim: 'up to one order of magnitude more power efficient'\n\
         model: max GPU/OPU energy ratio in-memory regime = {headline_ratio:.1}x \
         (→ ∞ once B no longer fits GPU memory); claim {}",
        if headline_ratio >= 8.0 { "HOLDS" } else { "DIVERGES" }
    );

    // Whole-training-run energy at paper scale: 10 epochs x 60k samples,
    // at the largest projection that still fits GPU memory (1e5 x 2.5e4
    // f32 = 10 GB; beyond that only the OPU can run it at all).
    let projections = 10 * 60_000;
    let (gd_in, gd_out) = (100_000usize, 25_000usize);
    assert!(gpu.supports(gd_in, gd_out));
    println!(
        "\nfull paper training run ({projections} projections, {gd_in}x{gd_out}):\n  \
         OPU: {:.0} J ({:.1} Wh)   GPU (largest fitting): {:.0} J   ratio {:.1}x",
        opu.energy(projections),
        opu.energy(projections) / 3600.0,
        gpu.energy(gd_in, gd_out, 1, projections),
        gpu.energy(gd_in, gd_out, 1, projections) / opu.energy(projections),
    );
    Ok(())
}
