//! E2 — projection throughput: "1500 random projections of size 1e5 per
//! second" and "competitive with GPUs at large scale".
//!
//! Measured series (this host) + modeled series (paper OPU, V100
//! roofline) over output dimension; the payload is the crossover where
//! the OPU's flat frame rate beats the GPU's shrinking mat-vec rate.

use litl::bench::{fmt_rate, Bench};
use litl::exec::ThreadPool;
use litl::optics::medium::TransmissionMatrix;
use litl::optics::{OpticalOpu, OpuParams};
use litl::sim::power::{CpuModel, GpuModel, Holography, OpuModel};
use litl::tensor::{matmul, matmul_pooled, Tensor};
use litl::util::rng::Pcg64;

fn ternary(rows: usize, cols: usize, seed: u64) -> Tensor {
    let mut rng = Pcg64::seeded(seed);
    let data = (0..rows * cols)
        .map(|_| (rng.next_below(3) as i64 - 1) as f32)
        .collect();
    Tensor::from_vec(&[rows, cols], data)
}

fn main() -> anyhow::Result<()> {
    litl::util::logging::init();
    let mut bench = Bench::new();
    let d_in = 10usize; // error dimension (MNIST classes)
    let batch = 128usize;

    // ---- measured: host matmul on executable shapes (CpuModel calib) --
    println!("E2: measuring host CPU projection (calibrates CpuModel)...");
    let mut cpu_macs = 0.0f64;
    for modes in [256usize, 1024, 4096] {
        let medium = TransmissionMatrix::sample(1, d_in, modes);
        let e = ternary(batch, d_in, 2);
        let m = bench.run(&format!("host matmul d_out={modes} batch={batch}"), || {
            let _ = matmul(&e, &medium.b_re);
        });
        cpu_macs = cpu_macs.max((d_in * modes * batch) as f64 / m.mean_s);
    }
    let cpu = CpuModel::measured(cpu_macs);
    println!("  calibrated: {:.2} GMAC/s sustained\n", cpu_macs / 1e9);

    // ---- measured: multi-core host baseline (honest silicon row) ----
    // Row-block-parallel matmul, bitwise identical to the serial path.
    let cores = litl::exec::host_cores();
    let pool = ThreadPool::new(cores, 4 * cores);
    for modes in [1024usize, 4096] {
        let medium = TransmissionMatrix::sample(1, d_in, modes);
        let e = ternary(batch, d_in, 2);
        bench.run(
            &format!("host matmul pooled x{cores} d_out={modes} batch={batch}"),
            || {
                let _ = matmul_pooled(&e, &medium.b_re, &pool);
            },
        );
    }

    // ---- measured: the optics simulation itself ----
    for modes in [256usize, 1024] {
        let medium = TransmissionMatrix::sample(3, d_in, modes);
        let mut opu = OpticalOpu::new(OpuParams::default(), medium, 5);
        let e = ternary(batch, d_in, 6);
        bench.run(&format!("OPU physics sim d_out={modes} batch={batch}"), || {
            let _ = opu.project(&e).unwrap();
        });
    }
    bench.table("measured on this host (1 core)");

    // ---- modeled: the paper's regime ----
    let opu = OpuModel::paper(Holography::OffAxis);
    let gpu = GpuModel::v100();
    println!("\n== modeled projections/second vs output dimension (input dim 1e6) ==");
    println!(
        "{:>10} {:>14} {:>14} {:>14} {:>14}",
        "d_out", "OPU (paper)", "GPU batch=1", "GPU batch=128", "CPU (meas.)"
    );
    let d_in_big = 1_000_000usize;
    for d_out in [100usize, 1_000, 10_000, 100_000, 1_000_000] {
        let opu_r = opu
            .throughput(d_in_big, d_out)
            .map(fmt_rate)
            .unwrap_or("— (>max)".into());
        let gpu1 = gpu
            .throughput(d_in_big, d_out, 1)
            .map(fmt_rate)
            .unwrap_or("— (OOM)".into());
        let gpu128 = gpu
            .throughput(d_in_big, d_out, 128)
            .map(fmt_rate)
            .unwrap_or("— (OOM)".into());
        let cpu_r = fmt_rate(cpu.throughput(d_in_big, d_out));
        println!("{d_out:>10} {opu_r:>14} {gpu1:>14} {gpu128:>14} {cpu_r:>14}");
    }

    // Crossover: smallest d_out where OPU >= GPU batch-1.
    let mut crossover = None;
    for d_out in (1..=200).map(|k| k * 1000) {
        match gpu.throughput(d_in_big, d_out, 1) {
            None => {
                crossover = crossover.or(Some(d_out));
                break;
            }
            Some(g) => {
                if opu.throughput(d_in_big, d_out).unwrap_or(0.0) >= g {
                    crossover = Some(d_out);
                    break;
                }
            }
        }
    }
    println!(
        "\ncrossover (OPU ≥ GPU batch-1, unbatched DFA feedback): d_out ≈ {}",
        crossover.map(|d| d.to_string()).unwrap_or("none".into())
    );
    println!(
        "paper headline: 1500 proj/s @ d_out=1e5 → model gives {}",
        opu.throughput(d_in_big, 100_000).map(fmt_rate).unwrap()
    );
    println!(
        "effective compute at that size: {:.1} TMAC/s ('hundred billion parameters' per frame)",
        opu.effective_macs(d_in_big, 100_000).unwrap() / 1e12
    );
    Ok(())
}
