//! E6 — streamed projection engine: memory-less transmission media at
//! 1e5+ modes.
//!
//! The paper's scalability claim is that the OPU projects at dimensions
//! "inaccessible to GPUs" because the medium is physical — the
//! transmission matrix is never stored.  This bench measures the
//! simulator's realization of that claim (`optics::stream`): a mode
//! sweep 1e4 → 1e6 through the streamed engine, reporting throughput
//! and the peak-RSS proxy (TM bytes resident vs the dense slice), plus
//! the per-tile clock/energy attribution of the generation cost.
//!
//! Knobs (all env vars, for the CI smoke job):
//! * `E6_MODES=100000`   — run a single size instead of the sweep
//! * `E6_D_IN`, `E6_BATCH` — shape overrides
//! * `E6_PROVE_CEILING=1` — additionally *prove* the memory ceiling:
//!   `try_reserve` the dense medium's buffers and require the
//!   allocation to FAIL.  Run under `ulimit -v` (the CI `stream-smoke`
//!   job uses 1 GiB, where the 2048×1e5 dense medium's 1.6 GB cannot
//!   exist while the streamed projection completes).
//! * `E6_TILE_CACHE_MB=N` — attach the bounded cross-step tile cache to
//!   the sweep medium and project twice per size (the second pass
//!   exercises hits); the smoke job runs this under the same 1 GiB
//!   ceiling to prove budget + streaming still fit.
//! * `E6_GENKERNEL_NORMALS`, `E6_GENKERNEL_MIN_SPEEDUP` — size of the
//!   E6.0 kernel comparison and an optional hard floor on batched/scalar
//!   (the CI `gen-kernel-bench` job sets a floor: a batched kernel slower
//!   than the scalar walk fails the job; both paths share the crate's
//!   polynomial transcendentals, and E6.0 also times a bench-local
//!   libm-based fill so the record tracks poly-vs-libm).
//! * `E6_CACHE_HIT_MIN_SCALING` — hard floor on the E6.4 contention
//!   sweep: per-thread hit throughput at the maximum stripe count,
//!   relative to the single-stripe cache at the same thread count.
//!   Below the floor (lock striping stopped paying for itself) the
//!   bench fails.

use std::collections::BTreeMap;
use std::time::Instant;

use litl::coordinator::projector::{NativeOpticalProjector, Projector};
use litl::optics::medium::TransmissionMatrix;
use litl::optics::stream::{Medium, StreamedMedium};
use litl::optics::OpuParams;
use litl::sim::power::CpuModel;
use litl::tensor::{matmul, Tensor};
use litl::util::json::Json;
use litl::util::rng::{Pcg64, NORMAL_LANE};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

/// Bench-local Box–Muller fill through the **host libm** (`f64::ln`,
/// `f64::sin_cos`): the same PCG walk and op sequence as
/// `fill_normal_scalar`, with the crate's polynomial kernels swapped out
/// for whatever transcendentals this glibc ships.  Values agree with the
/// crate kernels to ~1 ulp, not bitwise — this exists purely as the
/// speed baseline the `poly_vs_libm_speedup` record field is measured
/// against.
fn fill_normal_libm(rng: &mut Pcg64, out: &mut [f32]) {
    let mut i = 0;
    while i < out.len() {
        let u = loop {
            let u = rng.next_f64();
            if u > 1e-300 {
                break u;
            }
        };
        let v = rng.next_f64();
        let r = (-2.0 * u.ln()).sqrt();
        let (sin, cos) = (2.0 * std::f64::consts::PI * v).sin_cos();
        out[i] = (r * cos) as f32;
        i += 1;
        if i < out.len() {
            out[i] = (r * sin) as f32;
            i += 1;
        }
    }
}

fn ternary(rows: usize, cols: usize, seed: u64) -> Tensor {
    let mut rng = Pcg64::seeded(seed);
    let mut e = Tensor::zeros(&[rows, cols]);
    for v in e.data_mut() {
        *v = (rng.next_below(3) as i64 - 1) as f32;
    }
    e
}

fn main() -> anyhow::Result<()> {
    litl::util::logging::init();
    let smoke = std::env::var("E6_MODES").is_ok();
    let d_in = env_usize("E6_D_IN", if smoke { 2048 } else { 256 });
    let batch = env_usize("E6_BATCH", if smoke { 1 } else { 2 });
    let modes_sweep: Vec<usize> = if smoke {
        vec![env_usize("E6_MODES", 100_000)]
    } else {
        vec![10_000, 100_000, 1_000_000]
    };
    let seed = 9u64;

    // ---- E6.0: generation kernel — batched lanes vs the scalar walk ----
    // The Box–Muller pair walk is the streamed engine's hot loop; the
    // lane kernel must be bitwise identical AND at least as fast.  Emits
    // the `e6_genkernel` JSON record (normals/s, both kernels).
    {
        let n = env_usize("E6_GENKERNEL_NORMALS", 4_000_000);
        let mut buf = vec![0.0f32; n];
        // Bitwise canary over an odd length (spare carry included).
        let mut a = Pcg64::new(9, 1);
        let mut b = Pcg64::new(9, 1);
        let mut xa = vec![0.0f32; 1001];
        let mut xb = vec![0.0f32; 1001];
        a.fill_normal_scalar(&mut xa);
        b.fill_normal(&mut xb);
        assert!(
            xa.iter().zip(&xb).all(|(x, y)| x.to_bits() == y.to_bits()),
            "canary: batched kernel != scalar walk"
        );
        let mut scalar_best = f64::INFINITY;
        for _ in 0..3 {
            let mut rng = Pcg64::new(42, 7);
            let t0 = Instant::now();
            rng.fill_normal_scalar(&mut buf);
            scalar_best = scalar_best.min(t0.elapsed().as_secs_f64());
        }
        let scalar_tail = buf[n - 1];
        let mut batched_best = f64::INFINITY;
        for _ in 0..3 {
            let mut rng = Pcg64::new(42, 7);
            let t0 = Instant::now();
            rng.fill_normal(&mut buf);
            batched_best = batched_best.min(t0.elapsed().as_secs_f64());
        }
        // Same seed, same bits — and the compare keeps both fills live.
        assert_eq!(scalar_tail.to_bits(), buf[n - 1].to_bits());
        // Host-libm baseline: same walk, glibc transcendentals.  Values
        // agree to ~1 ulp (checked loosely here; the exact contract is
        // poly == scalar-oracle bitwise, pinned above and in the test
        // suites) — this timing is what poly_vs_libm is measured from.
        let mut libm_best = f64::INFINITY;
        let mut libm_buf = vec![0.0f32; n];
        for _ in 0..3 {
            let mut rng = Pcg64::new(42, 7);
            let t0 = Instant::now();
            fill_normal_libm(&mut rng, &mut libm_buf);
            libm_best = libm_best.min(t0.elapsed().as_secs_f64());
        }
        assert!(
            libm_buf[n - 1].is_finite()
                && (libm_buf[n - 1] - buf[n - 1]).abs() <= 1e-5 * buf[n - 1].abs().max(1.0),
            "libm baseline diverged from the crate kernels: {} vs {}",
            libm_buf[n - 1],
            buf[n - 1]
        );
        let scalar_rate = n as f64 / scalar_best;
        let batched_rate = n as f64 / batched_best;
        let libm_rate = n as f64 / libm_best;
        let speedup = batched_rate / scalar_rate;
        let poly_vs_libm = batched_rate / libm_rate;
        println!(
            "== E6.0: Box–Muller kernel ({n} normals, lane {NORMAL_LANE}, best of 3) ==\n\
             scalar  {}/s | batched {}/s | libm-walk {}/s | speedup {speedup:.2}x \
             | poly-vs-libm {poly_vs_libm:.2}x",
            litl::bench::fmt_rate(scalar_rate),
            litl::bench::fmt_rate(batched_rate),
            litl::bench::fmt_rate(libm_rate),
        );
        let mut rec = BTreeMap::new();
        rec.insert("bench".to_string(), Json::Str("e6_genkernel".to_string()));
        rec.insert("normals".to_string(), Json::Num(n as f64));
        rec.insert("lane".to_string(), Json::Num(NORMAL_LANE as f64));
        rec.insert("scalar_normals_per_s".to_string(), Json::Num(scalar_rate));
        rec.insert("batched_normals_per_s".to_string(), Json::Num(batched_rate));
        rec.insert("libm_normals_per_s".to_string(), Json::Num(libm_rate));
        rec.insert("speedup".to_string(), Json::Num(speedup));
        rec.insert("poly_vs_libm_speedup".to_string(), Json::Num(poly_vs_libm));
        println!("{}", Json::Obj(rec).to_string_compact());
        if let Ok(raw) = std::env::var("E6_GENKERNEL_MIN_SPEEDUP") {
            // A malformed floor must fail loudly, not silently tighten
            // the gate to some default.
            let min: f64 = raw
                .parse()
                .map_err(|e| anyhow::anyhow!("E6_GENKERNEL_MIN_SPEEDUP '{raw}': {e}"))?;
            anyhow::ensure!(
                speedup >= min,
                "batched Box–Muller kernel regressed: {speedup:.2}x < required {min:.2}x"
            );
        }
    }

    // ---- correctness canary (always): streamed == dense, bitwise ----
    {
        let (cd, cm) = (32usize, 512usize);
        let dense = TransmissionMatrix::sample(seed, cd, cm);
        let sm = StreamedMedium::new(seed, cd, cm);
        let e = ternary(3, cd, 1);
        let (s1, s2) = sm.project(&e);
        assert_eq!(s1, matmul(&e, &dense.b_re), "canary: streamed != dense (re)");
        assert_eq!(s2, matmul(&e, &dense.b_im), "canary: streamed != dense (im)");
        println!("canary: streamed projection bitwise-equals dense at {cd}x{cm}: OK");
    }

    // ---- memory-ceiling proof (smoke mode, under ulimit -v) ----
    if std::env::var("E6_PROVE_CEILING").is_ok() {
        let modes = modes_sweep[0];
        let entries = d_in * modes;
        let mut quad: Vec<f32> = Vec::new();
        // Both quadratures of the dense medium in one reservation: this
        // is what `TransmissionMatrix::sample` would need resident.
        let dense_ok = quad.try_reserve_exact(2 * entries).is_ok();
        drop(quad);
        anyhow::ensure!(
            !dense_ok,
            "dense medium ({:.2} GB) fit under the memory ceiling — the \
             ceiling does not enforce the memory-less guarantee; lower \
             ulimit -v or raise E6_D_IN",
            (2 * entries * 4) as f64 / 1e9
        );
        println!(
            "ceiling proof: dense [{}x{}] medium allocation FAILS under the \
             current address-space limit (as it must); streaming instead…",
            d_in, modes
        );
    }

    // ---- E6.1: mode sweep through the streamed engine ----
    println!("\n== E6.1: streamed projection sweep (d_in={d_in}, batch={batch}) ==");
    println!(
        "{:>10} {:>11} {:>12} {:>13} {:>13} {:>12} {:>11}",
        "modes", "wall", "frames/s", "entries/s", "dense bytes", "resident", "gen J"
    );
    let cache_mb = env_usize("E6_TILE_CACHE_MB", 0);
    let mut rows: Vec<Json> = Vec::new();
    for &modes in &modes_sweep {
        // Pool-parallel tiles: the deployed configuration (the trainer
        // attaches the shared pool); parity with the serial walk is
        // pinned pool-independent in stream.rs/stream_parity.rs.
        let sm = StreamedMedium::new(seed, d_in, modes)
            .with_pool(litl::exec::shared_pool())
            .with_tile_cache_mb(cache_mb);
        let e = ternary(batch, d_in, 2);
        let t0 = Instant::now();
        let (p1, _p2) = sm.project(&e);
        let wall = t0.elapsed().as_secs_f64();
        // Snapshot BEFORE any warm pass: the e6_streaming record stays
        // cold-pass-only, comparable across cache-on/off runs (the knob
        // is recorded alongside).
        let st = sm.stats();
        if cache_mb > 0 {
            // Second pass over the same frames: cross-step hits, under
            // the same memory ceiling as the first (smoke mode runs this
            // below `ulimit -v` — budget + streaming must still fit).
            let t1 = Instant::now();
            let (q1, _q2) = sm.project(&e);
            assert_eq!(p1, q1, "cached pass must be bitwise the first");
            let warm = t1.elapsed().as_secs_f64();
            let st_warm = sm.stats();
            anyhow::ensure!(
                st_warm.cache_resident_bytes <= st_warm.cache_budget_bytes,
                "cache over budget: {} > {}",
                st_warm.cache_resident_bytes,
                st_warm.cache_budget_bytes
            );
            println!(
                "  tile cache {cache_mb} MiB: warm pass {} (cold {}), \
                 {} hits / {} misses, resident {:.1} MB of {:.1} MB budget",
                litl::bench::fmt_s(warm),
                litl::bench::fmt_s(wall),
                st_warm.cache_hits,
                st_warm.cache_misses,
                st_warm.cache_resident_bytes as f64 / 1e6,
                st_warm.cache_budget_bytes as f64 / 1e6,
            );
        }
        // Per-tile clock/energy attribution: generation is host
        // simulation cost, charged at the CPU package power.
        let entries_per_s = st.bytes_generated as f64 / 8.0 / st.gen_seconds.max(1e-12);
        let cpu = CpuModel::measured(entries_per_s);
        let gen_joules = cpu.energy_for_secs(st.gen_seconds);
        let frames_per_s = batch as f64 / wall;
        let dense_bytes = sm.dense_bytes();
        let resident = sm.resident_tm_bytes();
        println!(
            "{:>10} {:>11} {:>12} {:>13} {:>13} {:>12} {:>11}",
            modes,
            litl::bench::fmt_s(wall),
            litl::bench::fmt_rate(frames_per_s),
            litl::bench::fmt_rate(entries_per_s),
            format!("{:.1} MB", dense_bytes as f64 / 1e6),
            format!("{:.1} KB", resident as f64 / 1e3),
            format!("{gen_joules:.2}"),
        );
        // Sanity: unit-variance modes at every size.
        let nnz_row0 = (0..d_in).filter(|&r| e.at(0, r) != 0.0).count() as f64;
        let var: f64 = p1.data()[..modes]
            .iter()
            .map(|&x| (x as f64).powi(2))
            .sum::<f64>()
            / modes as f64;
        assert!(
            (var - nnz_row0 / 2.0).abs() < 0.1 * nnz_row0.max(1.0),
            "variance {var} vs theory {}",
            nnz_row0 / 2.0
        );
        // The memory-less guarantee, as numbers.  With a cache attached,
        // residency is the declared budget instead of tile scratch, so
        // the scratch-vs-dense comparison only applies cache-off.
        if cache_mb == 0 {
            assert!(resident * 100 < dense_bytes || modes < 100_000);
        }
        let mut row = BTreeMap::new();
        row.insert("modes".to_string(), Json::Num(modes as f64));
        row.insert("tile_cache_mb".to_string(), Json::Num(cache_mb as f64));
        row.insert("wall_s".to_string(), Json::Num(wall));
        row.insert("frames_per_s".to_string(), Json::Num(frames_per_s));
        row.insert("entries_per_s".to_string(), Json::Num(entries_per_s));
        row.insert("dense_bytes".to_string(), Json::Num(dense_bytes as f64));
        row.insert(
            "resident_tm_bytes".to_string(),
            Json::Num(resident as f64),
        );
        row.insert(
            "bytes_generated".to_string(),
            Json::Num(st.bytes_generated as f64),
        );
        row.insert("gen_seconds".to_string(), Json::Num(st.gen_seconds));
        row.insert("gen_joules".to_string(), Json::Num(gen_joules));
        rows.push(Json::Obj(row));
    }

    // ---- E6.3: cross-step tile-cache sweep (hit rate / steps/s vs
    // budget at 1e5 modes) — the `e6_tile_cache` JSON record.  Budget 0
    // is the regenerate-everything baseline; a budget covering the
    // working set must serve ≥ 90% from cache from step 2 on (asserted,
    // so the claim is CI-enforced, not aspirational).
    if !smoke {
        let modes = 100_000usize;
        let steps = 4usize;
        let e = ternary(batch, d_in, 5);
        let active_rows = (0..d_in)
            .filter(|&r| (0..batch).any(|bi| e.at(bi, r) != 0.0))
            .count();
        // Every active row regenerates its full mode width per step.
        let working_set = active_rows * modes * 8;
        println!(
            "\n== E6.3: tile-cache sweep (modes={modes}, d_in={d_in}, batch={batch}, \
             working set {:.1} MB) ==",
            working_set as f64 / 1e6
        );
        println!(
            "{:>10} {:>6} {:>11} {:>10} {:>10} {:>12}",
            "budget", "step", "wall", "steps/s", "hit rate", "resident"
        );
        let mut cache_rows: Vec<Json> = Vec::new();
        for budget_mb in [0usize, 64, 128, 256] {
            let sm = StreamedMedium::new(seed, d_in, modes)
                .with_pool(litl::exec::shared_pool())
                .with_tile_cache_mb(budget_mb);
            let mut prev_hits = 0u64;
            let mut prev_misses = 0u64;
            for step in 0..steps {
                let t0 = Instant::now();
                let _ = sm.project(&e);
                let wall = t0.elapsed().as_secs_f64();
                let st = sm.stats();
                let dh = st.cache_hits - prev_hits;
                let dm = st.cache_misses - prev_misses;
                prev_hits = st.cache_hits;
                prev_misses = st.cache_misses;
                let lookups = dh + dm;
                let hit_rate = if lookups == 0 {
                    0.0
                } else {
                    dh as f64 / lookups as f64
                };
                println!(
                    "{:>10} {:>6} {:>11} {:>10} {:>10} {:>12}",
                    format!("{budget_mb} MiB"),
                    step + 1,
                    litl::bench::fmt_s(wall),
                    litl::bench::fmt_rate(1.0 / wall.max(1e-12)),
                    format!("{:.1}%", 100.0 * hit_rate),
                    format!("{:.1} MB", st.cache_resident_bytes as f64 / 1e6),
                );
                anyhow::ensure!(
                    st.cache_resident_bytes <= st.cache_budget_bytes,
                    "cache over budget at {budget_mb} MiB"
                );
                if budget_mb * 1024 * 1024 >= working_set && step >= 1 {
                    anyhow::ensure!(
                        hit_rate >= 0.9,
                        "budget {budget_mb} MiB covers the {working_set}-byte working \
                         set but step {} hit rate is only {hit_rate:.3}",
                        step + 1
                    );
                }
                let mut row = BTreeMap::new();
                row.insert("budget_mb".to_string(), Json::Num(budget_mb as f64));
                row.insert("step".to_string(), Json::Num((step + 1) as f64));
                row.insert("wall_s".to_string(), Json::Num(wall));
                row.insert(
                    "steps_per_s".to_string(),
                    Json::Num(1.0 / wall.max(1e-12)),
                );
                row.insert("hit_rate".to_string(), Json::Num(hit_rate));
                row.insert(
                    "cache_resident_bytes".to_string(),
                    Json::Num(st.cache_resident_bytes as f64),
                );
                row.insert(
                    "bytes_generated".to_string(),
                    Json::Num(st.bytes_generated as f64),
                );
                cache_rows.push(Json::Obj(row));
            }
        }
        let mut rec = BTreeMap::new();
        rec.insert("bench".to_string(), Json::Str("e6_tile_cache".to_string()));
        rec.insert("modes".to_string(), Json::Num(modes as f64));
        rec.insert("d_in".to_string(), Json::Num(d_in as f64));
        rec.insert("batch".to_string(), Json::Num(batch as f64));
        rec.insert(
            "working_set_bytes".to_string(),
            Json::Num(working_set as f64),
        );
        rec.insert("results".to_string(), Json::Arr(cache_rows));
        println!("{}", Json::Obj(rec).to_string_compact());
    }

    // ---- E6.4: striped-cache contention sweep (threads × stripes at a
    // fixed budget) — the `e6_cache_contention` JSON record.  Every cell
    // warms one fully-resident cache, then hammers it with T replica
    // threads doing all-hit projections; the figure of merit is
    // per-thread hit throughput (lookups/s/thread), which a global lock
    // flattens as T grows and striping should hold up.  Runs in smoke
    // mode too (small fixed shape, ~MiB residency): the gen-kernel CI
    // job gates on it via `E6_CACHE_HIT_MIN_SCALING`.
    {
        let (cd, cm, tile) = (64usize, 4096usize, 128usize);
        let budget_mb = 4usize;
        let reps = 30usize;
        let tiles_per_proj = cd * cm.div_ceil(tile);
        let cores = litl::exec::host_cores().max(1);
        let threads_sweep: Vec<usize> =
            [1usize, 2, 4, 8].into_iter().filter(|&t| t == 1 || t <= cores).collect();
        let stripes_sweep = [1usize, 4, 16];
        let e = ternary(2, cd, 11);
        let want = StreamedMedium::new(seed, cd, cm).with_tile_cols(tile).project(&e);
        println!(
            "\n== E6.4: cache contention sweep (d_in={cd}, modes={cm}, tile={tile}, \
             budget {budget_mb} MiB, {reps} reps, best of 3) =="
        );
        println!(
            "{:>8} {:>8} {:>11} {:>16}",
            "threads", "stripes", "wall", "hits/s/thread"
        );
        let mut cells: Vec<Json> = Vec::new();
        let mut per_thread_rate: BTreeMap<(usize, usize), f64> = BTreeMap::new();
        for &stripes in &stripes_sweep {
            for &threads in &threads_sweep {
                let sm = StreamedMedium::new(seed, cd, cm)
                    .with_tile_cols(tile)
                    .with_tile_cache_mb_striped(budget_mb, stripes);
                // Warm pass: the whole working set fits the budget, so
                // every timed lookup below is a hit — and the bits must
                // equal the uncached reference before anything is timed.
                assert_eq!(sm.project(&e), want, "cached != uncached ({stripes} stripes)");
                let st = sm.stats();
                anyhow::ensure!(
                    st.cache_resident_bytes <= st.cache_budget_bytes,
                    "contention sweep over budget at {stripes} stripes"
                );
                let mut wall = f64::INFINITY;
                for _ in 0..3 {
                    let t0 = Instant::now();
                    std::thread::scope(|s| {
                        for _ in 0..threads {
                            let sm = sm.clone();
                            let (e, want) = (&e, &want);
                            s.spawn(move || {
                                for _ in 0..reps {
                                    assert_eq!(&sm.project(e), want);
                                }
                            });
                        }
                    });
                    wall = wall.min(t0.elapsed().as_secs_f64().max(1e-12));
                }
                // Each thread performs `reps` all-hit projections of
                // `tiles_per_proj` lookups; per-thread throughput is
                // thread-count-invariant under perfect scaling.
                let per_thread = (reps * tiles_per_proj) as f64 / wall;
                per_thread_rate.insert((threads, stripes), per_thread);
                println!(
                    "{:>8} {:>8} {:>11} {:>16}",
                    threads,
                    stripes,
                    litl::bench::fmt_s(wall),
                    litl::bench::fmt_rate(per_thread),
                );
                let mut row = BTreeMap::new();
                row.insert("threads".to_string(), Json::Num(threads as f64));
                row.insert("stripes".to_string(), Json::Num(stripes as f64));
                row.insert("wall_s".to_string(), Json::Num(wall));
                row.insert("hits_per_s_per_thread".to_string(), Json::Num(per_thread));
                cells.push(Json::Obj(row));
            }
        }
        let mut rec = BTreeMap::new();
        rec.insert(
            "bench".to_string(),
            Json::Str("e6_cache_contention".to_string()),
        );
        rec.insert("d_in".to_string(), Json::Num(cd as f64));
        rec.insert("modes".to_string(), Json::Num(cm as f64));
        rec.insert("tile_cols".to_string(), Json::Num(tile as f64));
        rec.insert("budget_mb".to_string(), Json::Num(budget_mb as f64));
        rec.insert("reps".to_string(), Json::Num(reps as f64));
        rec.insert(
            "tiles_per_projection".to_string(),
            Json::Num(tiles_per_proj as f64),
        );
        rec.insert("host_cores".to_string(), Json::Num(cores as f64));
        rec.insert("results".to_string(), Json::Arr(cells));
        println!("{}", Json::Obj(rec).to_string_compact());
        if let Ok(raw) = std::env::var("E6_CACHE_HIT_MIN_SCALING") {
            // Malformed floors fail loudly, same as the gen-kernel gate.
            let min: f64 = raw
                .parse()
                .map_err(|err| anyhow::anyhow!("E6_CACHE_HIT_MIN_SCALING '{raw}': {err}"))?;
            let t_max = *threads_sweep.last().unwrap();
            let s_max = *stripes_sweep.last().unwrap();
            let base = per_thread_rate[&(t_max, 1)];
            let striped = per_thread_rate[&(t_max, s_max)];
            let scaling = striped / base;
            println!(
                "contention gate: {s_max}-stripe vs 1-stripe per-thread hit \
                 throughput at {t_max} threads = {scaling:.2}x (floor {min:.2}x)"
            );
            anyhow::ensure!(
                scaling >= min,
                "striped cache stopped paying for itself: {s_max} stripes at \
                 {t_max} threads is {scaling:.2}x the single-stripe rate \
                 (< required {min:.2}x)"
            );
        }
    }

    // ---- E6.2: the full optical device over a streamed medium ----
    // Frame clock unchanged (the device never knows the backing); the
    // generation clock is the only extra accounting.
    let opt_modes = *modes_sweep.iter().min().unwrap();
    let sm = StreamedMedium::new(seed, d_in, opt_modes)
        .with_pool(litl::exec::shared_pool());
    let gen_clock = sm.gen_clock().clone();
    let params = OpuParams {
        max_modes: opt_modes.max(OpuParams::default().max_modes),
        ..OpuParams::default()
    };
    let mut opu =
        NativeOpticalProjector::with_medium(params, Medium::Streamed(sm), 7);
    let e = ternary(batch, d_in, 3);
    let t0 = Instant::now();
    let _ = opu.project(&e)?;
    let opt_wall = t0.elapsed().as_secs_f64();
    println!(
        "\n== E6.2: optical device over streamed medium ({d_in}→{opt_modes}) ==\n\
         wall {} | device frame time {} ({} frames @ 1.5 kHz) | tile-gen time {}",
        litl::bench::fmt_s(opt_wall),
        litl::bench::fmt_s(opu.sim_seconds()),
        batch,
        litl::bench::fmt_s(gen_clock.now_secs()),
    );

    let mut record = BTreeMap::new();
    record.insert("bench".to_string(), Json::Str("e6_streaming".to_string()));
    record.insert("d_in".to_string(), Json::Num(d_in as f64));
    record.insert("batch".to_string(), Json::Num(batch as f64));
    record.insert(
        "host_cores".to_string(),
        Json::Num(litl::exec::host_cores() as f64),
    );
    record.insert("results".to_string(), Json::Arr(rows));
    println!("{}", Json::Obj(record).to_string_compact());
    println!(
        "\nthe physical device pays ZERO of the generation cost — light does\n\
         the matmul; the frame clock (1/1500 s per exposure) is the only\n\
         device time axis.  Generation seconds above are what this host pays\n\
         to *emulate* the scattering numerically, tile by tile."
    );
    Ok(())
}
