//! E5 — ablations of the method's design choices:
//!
//! 1. **Eq. 4 threshold θ** — the paper fixes θ = 0.1; sweep it and
//!    report end-of-budget accuracy (the quantization-aggressiveness vs
//!    signal trade-off).
//! 2. **Camera noise (photon budget)** — the axis that separates the
//!    paper's 97.6 % (digital ternary) from 95.8 % (optical): sweep n_ph
//!    and report accuracy degradation.
//! 3. **Feedback alignment** — cos∠(DFA update, BP gradient) before and
//!    after training: the mechanism that makes DFA learn at all.
//!
//! env: LITL_BENCH_STEPS, LITL_BENCH_TRAIN (same as e1).

use litl::config::{Algo, TrainConfig};
use litl::coordinator::host::{HostAlgo, HostTrainer};
use litl::coordinator::projector::DigitalProjector;
use litl::coordinator::{align, Trainer};
use litl::data::{self, Split};
use litl::optics::medium::TransmissionMatrix;
use litl::util::rng::Pcg64;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn run_budgeted(
    mut cfg: TrainConfig,
    ds: &litl::data::Dataset,
    steps: usize,
) -> anyhow::Result<f64> {
    cfg.seed = 42;
    let mut tr = Trainer::new(cfg)?;
    tr.warmup()?;
    let batch = tr.model().batch;
    let mut rng = Pcg64::seeded(1);
    let mut done = 0usize;
    'outer: loop {
        for (x, y) in ds.batches(Split::Train, batch, &mut rng) {
            tr.train_step(&x, &y)?;
            done += 1;
            if done >= steps {
                break 'outer;
            }
        }
    }
    Ok(tr.evaluate(ds, Split::Test)?.accuracy)
}

fn main() -> anyhow::Result<()> {
    litl::util::logging::init();
    let steps = env_usize("LITL_BENCH_STEPS", 900);
    let train_size = env_usize("LITL_BENCH_TRAIN", 6_000);
    let test_size = 1_000usize;
    let ds = data::load_or_synth(42, train_size, test_size)?;
    let base = TrainConfig {
        artifact_config: "small".into(),
        train_size,
        test_size,
        lr: 0.001,
        ..TrainConfig::default()
    };

    // ---- E5.1: threshold sweep (digital ternary DFA) ----
    println!("== E5.1: Eq. 4 threshold sweep (digital ternary DFA, {steps} steps) ==");
    println!("{:>8} {:>12}", "θ", "accuracy");
    for theta in [0.02f32, 0.05, 0.1, 0.2, 0.4] {
        let mut cfg = base.clone();
        cfg.algo = Algo::DfaTernary;
        cfg.theta = theta;
        let acc = run_budgeted(cfg, &ds, steps)?;
        let marker = if (theta - 0.1).abs() < 1e-6 { "  <- paper" } else { "" };
        println!("{theta:>8} {:>11.2}%{marker}", acc * 100.0);
    }

    // ---- E5.2: photon-budget sweep (optical DFA) ----
    println!("\n== E5.2: camera noise sweep (optical DFA, {steps} steps) ==");
    println!("{:>10} {:>10} {:>12}", "n_ph", "read σ", "accuracy");
    for (n_ph, read_sigma) in [
        (1e9f32, 0.0f32),
        (1_000.0, 1.0),
        (100.0, 2.0),
        (10.0, 4.0),
        (2.0, 8.0),
        (0.5, 16.0),
        (0.1, 40.0),
    ] {
        let mut cfg = base.clone();
        cfg.algo = Algo::Optical;
        cfg.lr = 0.001;
        cfg.n_ph = Some(n_ph);
        cfg.read_sigma = Some(read_sigma);
        let acc = run_budgeted(cfg, &ds, steps)?;
        let marker = if (n_ph - 100.0).abs() < 1e-6 { "  <- default device" } else { "" };
        println!("{n_ph:>10} {read_sigma:>10} {:>11.2}%{marker}", acc * 100.0);
    }

    // ---- E5.3: feedback alignment over training (host oracle) ----
    println!("\n== E5.3: DFA/BP gradient alignment (cosine, host oracle) ==");
    let layers = &[784usize, 128, 128, 10];
    let medium = TransmissionMatrix::sample(99, 10, 128);
    let mut tr = HostTrainer::new(
        3,
        layers,
        0.001,
        HostAlgo::DfaFloat,
        Box::new(DigitalProjector::new(medium.clone())),
    );
    let mut probe = DigitalProjector::new(medium);
    let probe_idx: Vec<usize> = (0..512).collect();
    let (px, py) = ds.gather(Split::Train, &probe_idx);
    println!("{:>8} {:>10} {:>10}", "step", "layer1", "layer2");
    let mut rng = Pcg64::seeded(4);
    let mut done = 0usize;
    let checkpoints = [0usize, 25, 50, 100, 200, 400];
    'outer: loop {
        for (x, y) in ds.batches(Split::Train, 32, &mut rng) {
            if checkpoints.contains(&done) {
                let a = align::measure(&tr.mlp, &mut probe, &px, &py, -1.0)?;
                println!("{done:>8} {:>10.3} {:>10.3}", a.layer1, a.layer2);
            }
            tr.step(&x, &y)?;
            done += 1;
            if done > *checkpoints.last().unwrap() {
                break 'outer;
            }
        }
    }
    println!(
        "\nexpected shape: alignment rises from ~0 toward clearly positive —\n\
         Nøkland's feedback-alignment mechanism; noise/quantization lower it\n\
         but do not destroy it (that is why 95.8% is still achievable)."
    );
    Ok(())
}
