//! E1 — the paper's §III accuracy table (bench-budget version).
//!
//! Paper: MNIST 784-1024-1024-10 tanh + Adam, 10 epochs:
//!   optical DFA (ternary, lr .01) 95.8 % | digital DFA ternary (lr .001)
//!   97.6 % | digital DFA float 97.7 % | (BP reference ≈ 98 %).
//!
//! This bench regenerates the table's *shape* on a steps-bounded budget
//! (the full-scale run is `examples/mnist_dfa_train`): same model, same
//! four algorithms, synthetic MNIST-like digits, `small` artifacts by
//! default so the whole bench stays in CI budget.
//!
//! env: LITL_BENCH_CONFIG=paper LITL_BENCH_STEPS=N

use litl::config::{Algo, TrainConfig};
use litl::coordinator::Trainer;
use litl::data::{self, Split};
use litl::util::rng::Pcg64;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    litl::util::logging::init();
    let config = std::env::var("LITL_BENCH_CONFIG").unwrap_or("small".into());
    let steps = env_usize("LITL_BENCH_STEPS", 900);
    let train_size = env_usize("LITL_BENCH_TRAIN", 8_000);
    let test_size = env_usize("LITL_BENCH_TEST", 1_000);

    let ds = data::load_or_synth(42, train_size, test_size)?;
    println!(
        "E1 bench: config={config}, {steps} steps, {train_size}/{test_size} samples"
    );

    let rows: Vec<(Algo, f32, Option<f64>)> = vec![
        (Algo::Bp, 0.001, None),
        (Algo::DfaFloat, 0.001, Some(97.7)),
        (Algo::DfaTernary, 0.001, Some(97.6)),
        (Algo::Optical, 0.01, Some(95.8)),
    ];

    println!(
        "\n{:<14} {:>6} {:>10} {:>11} {:>11} {:>12}",
        "algo", "lr", "paper", "measured", "steps/s", "OPU sim s"
    );
    let mut measured = Vec::new();
    for (algo, lr, paper) in &rows {
        let cfg = TrainConfig {
            artifact_config: config.clone(),
            algo: *algo,
            train_size,
            test_size,
            lr: *lr,
            seed: 42,
            ..TrainConfig::default()
        };
        let mut tr = Trainer::new(cfg)?;
        tr.warmup()?;
        let batch = tr.model().batch;
        let mut rng = Pcg64::seeded(1);
        let t0 = std::time::Instant::now();
        let mut done = 0usize;
        'outer: loop {
            for (x, y) in ds.batches(Split::Train, batch, &mut rng) {
                tr.train_step(&x, &y)?;
                done += 1;
                if done >= steps {
                    break 'outer;
                }
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let ev = tr.evaluate(&ds, Split::Test)?;
        measured.push(ev.accuracy);
        println!(
            "{:<14} {:>6} {:>10} {:>10.2}% {:>11.1} {:>12.2}",
            algo.name(),
            lr,
            paper.map(|p| format!("{p:.1}%")).unwrap_or("—".into()),
            ev.accuracy * 100.0,
            done as f64 / wall,
            tr.sim_device_seconds(),
        );
    }

    // Shape assertions (reported, not fatal — this is a bench).
    let (bp, float, tern, optical) = (measured[0], measured[1], measured[2], measured[3]);
    let check = |label: &str, ok: bool| {
        println!("shape: {label}: {}", if ok { "OK" } else { "DIVERGES" });
    };
    println!();
    check(
        &format!("optical {:.1}% <= ternary {:.1}% (+2pt)", optical * 100.0, tern * 100.0),
        optical <= tern + 0.02,
    );
    check(
        &format!("ternary {:.1}% <= float {:.1}% (+2pt)", tern * 100.0, float * 100.0),
        tern <= float + 0.02,
    );
    check(
        &format!("float {:.1}% <= bp {:.1}% (+2pt)", float * 100.0, bp * 100.0),
        float <= bp + 0.02,
    );
    Ok(())
}
