//! E7 — serving control plane under load: many concurrent clients, a
//! mid-run shard kill, and the failover path's no-hang guarantee.
//!
//! Two passes over the same workload through a sharded projection
//! service of per-row-throttled digital replicas (batch partition,
//! failover + adaptive weights on):
//!
//! 1. **healthy** — every shard serves; measures the fleet's baseline
//!    rows/s.
//! 2. **degraded** — a kill switch turns one shard into a hard-error
//!    device ~30% into the run; the error streak trips it, its lane
//!    drains onto the survivors, and the run keeps going.
//!
//! The record reports the degraded/healthy throughput fraction next to
//! the ideal `(shards-1)/shards`, the number of failed frames (the
//! kill window — errors are allowed, hangs are not) and the hang count,
//! which must be zero.
//!
//! Env knobs:
//! * `E7_CLIENTS`, `E7_SUBMITS`, `E7_ROWS`, `E7_SHARDS` — workload
//!   shape (defaults 200 / 6 / 8 / 3).
//! * `E7_DEGRADED_MIN_FRAC=0.35` — hard floor on the degraded
//!   throughput fraction (the CI loadgen-smoke gate).
//! * `E7_FAULT_PLAN=seed=7,dev_err_ppm=40000` — replace the wall-clock
//!   kill timer with a seeded [`FaultPlanCfg`]: the victim shard dies
//!   permanently at the first arrival whose `dev_err` decision fires,
//!   so the kill point is a deterministic *arrival index*, reproducible
//!   run-to-run regardless of machine speed (the CI loadgen-smoke
//!   schedule).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use litl::config::Partition;
use litl::coordinator::projector::{DigitalProjector, Projector};
use litl::coordinator::service::{
    AdaptConfig, FailoverConfig, ProjectionClient, ShardServiceConfig, ShardedProjectionService,
};
use litl::metrics::Registry;
use litl::net::FaultPlanCfg;
use litl::optics::medium::TransmissionMatrix;
use litl::tensor::Tensor;
use litl::util::json::Json;
use litl::util::rng::Pcg64;

const D_IN: usize = 32;
const MODES: usize = 64;
/// Simulated device cost: makes throughput device-bound, so losing one
/// of `shards` replicas costs ~1/shards of it.
const US_PER_ROW: u64 = 100;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Throttled digital replica with two kill paths: a wall-clock switch
/// (the default timer schedule) and an optional seeded fault plan — the
/// first arrival whose `dev_err` decision fires kills the device
/// permanently, making the kill point a deterministic arrival index.
struct LoadDevice {
    inner: DigitalProjector,
    killed: Arc<AtomicBool>,
    faults: Option<FaultPlanCfg>,
    shard: u32,
    arrivals: u64,
    dead: bool,
}

impl Projector for LoadDevice {
    fn project(&mut self, frames: &Tensor) -> anyhow::Result<(Tensor, Tensor)> {
        if let Some(plan) = &self.faults {
            let n = self.arrivals;
            self.arrivals += 1;
            if self.dead || plan.dev_err(self.shard, n) {
                self.dead = true; // seeded kills are permanent, like the switch
                anyhow::bail!(
                    "shard {} killed by fault plan at arrival {n}",
                    self.shard
                );
            }
        }
        if self.killed.load(Ordering::Relaxed) {
            anyhow::bail!("shard killed by loadgen");
        }
        std::thread::sleep(Duration::from_micros(US_PER_ROW * frames.rows() as u64));
        self.inner.project(frames)
    }

    fn modes(&self) -> usize {
        self.inner.modes()
    }

    fn sim_seconds(&self) -> f64 {
        self.inner.sim_seconds()
    }

    fn energy_joules(&self) -> f64 {
        self.inner.energy_joules()
    }

    fn kind(&self) -> &'static str {
        "loadgen"
    }

    fn requires_ternary(&self) -> bool {
        true
    }
}

fn start_fleet(
    medium: &TransmissionMatrix,
    shards: usize,
    metrics: Registry,
    // The seeded plan arms ONLY the victim shard (the last one) so the
    // degraded pass kills exactly one replica, as the timer path does.
    plan: Option<FaultPlanCfg>,
) -> (ShardedProjectionService, Vec<Arc<AtomicBool>>) {
    let switches: Vec<Arc<AtomicBool>> =
        (0..shards).map(|_| Arc::new(AtomicBool::new(false))).collect();
    let devices: Vec<Box<dyn Projector + Send>> = switches
        .iter()
        .enumerate()
        .map(|(s, k)| {
            Box::new(LoadDevice {
                inner: DigitalProjector::new(medium.clone()),
                killed: k.clone(),
                faults: plan.filter(|_| s == shards - 1),
                shard: s as u32,
                arrivals: 0,
                dead: false,
            }) as Box<dyn Projector + Send>
        })
        .collect();
    let svc = ShardedProjectionService::start(
        devices,
        D_IN,
        ShardServiceConfig {
            max_batch: 32,
            queue_depth: 256,
            lane_depth: 8,
            partition: Partition::Batch,
            adapt: AdaptConfig {
                enabled: true,
                ..AdaptConfig::default()
            },
            failover: FailoverConfig {
                enabled: true,
                trip_errors: 2,
                stall_ms: 5_000,
                probation_ms: 600_000,
            },
            ..Default::default()
        },
        metrics,
    )
    .unwrap();
    (svc, switches)
}

struct LoadStats {
    ok_rows: u64,
    failed_frames: u64,
    hung_clients: u64,
    secs: f64,
}

/// Drive `clients` threads, each submitting `submissions` requests of
/// `rows` ternary frames and waiting (bounded) for every reply.
/// Optionally arms a kill switch after a delay.  Errors are tallied;
/// a reply that takes > 120 s counts as a hang.
fn drive(
    client: &ProjectionClient,
    clients: usize,
    submissions: usize,
    rows: usize,
    kill: Option<(Arc<AtomicBool>, Duration)>,
) -> LoadStats {
    let ok_rows = Arc::new(AtomicU64::new(0));
    let failed = Arc::new(AtomicU64::new(0));
    let hung = Arc::new(AtomicU64::new(0));
    let t0 = Instant::now();
    let killer = kill.map(|(switch, delay)| {
        std::thread::spawn(move || {
            std::thread::sleep(delay);
            switch.store(true, Ordering::Relaxed);
        })
    });
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let client = client.clone();
            let ok_rows = ok_rows.clone();
            let failed = failed.clone();
            let hung = hung.clone();
            std::thread::spawn(move || {
                let mut rng = Pcg64::seeded(7100 + c as u64);
                for _ in 0..submissions {
                    let mut e = Tensor::zeros(&[rows, D_IN]);
                    for v in e.data_mut() {
                        *v = (rng.next_below(3) as i64 - 1) as f32;
                    }
                    let reply = match client.submit(e) {
                        Ok(r) => r,
                        Err(_) => {
                            failed.fetch_add(1, Ordering::Relaxed);
                            continue;
                        }
                    };
                    match reply.wait_timeout(Duration::from_secs(120)) {
                        Ok(Some(Ok(_))) => {
                            ok_rows.fetch_add(rows as u64, Ordering::Relaxed);
                        }
                        Ok(_) => {
                            failed.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(_) => {
                            hung.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let secs = t0.elapsed().as_secs_f64();
    if let Some(k) = killer {
        let _ = k.join();
    }
    LoadStats {
        ok_rows: ok_rows.load(Ordering::Relaxed),
        failed_frames: failed.load(Ordering::Relaxed),
        hung_clients: hung.load(Ordering::Relaxed),
        secs,
    }
}

fn main() -> anyhow::Result<()> {
    litl::util::logging::init();
    let clients = env_usize("E7_CLIENTS", 200);
    let submissions = env_usize("E7_SUBMITS", 6);
    let rows = env_usize("E7_ROWS", 8);
    let shards = env_usize("E7_SHARDS", 3);
    anyhow::ensure!(shards >= 2, "E7_SHARDS must be >= 2 (one gets killed)");
    let plan = FaultPlanCfg::from_env("E7_FAULT_PLAN")?;
    if let Some(p) = &plan {
        anyhow::ensure!(
            p.dev_err_ppm > 0,
            "E7_FAULT_PLAN needs dev_err_ppm > 0 (that's the seeded kill)"
        );
    }
    let medium = TransmissionMatrix::sample(77, D_IN, MODES);

    println!(
        "== E7: serving control plane loadgen ({clients} clients x {submissions} x \
         {rows} rows, {shards} shards, kill schedule: {}) ==",
        plan.map(|p| format!("seeded [{p}]"))
            .unwrap_or_else(|| "wall-clock timer".to_string())
    );

    // Pass 1: healthy fleet baseline (never armed with the plan).
    let (svc, _switches) = start_fleet(&medium, shards, Registry::new(), None);
    let healthy = drive(&svc.client(), clients, submissions, rows, None);
    svc.shutdown();
    let healthy_rate = healthy.ok_rows as f64 / healthy.secs.max(1e-9);
    println!(
        "healthy : {:.0} rows/s ({} rows in {:.2}s, {} failed, {} hung)",
        healthy_rate, healthy.ok_rows, healthy.secs, healthy.failed_frames, healthy.hung_clients
    );
    anyhow::ensure!(
        healthy.failed_frames == 0 && healthy.hung_clients == 0,
        "healthy pass must be clean: {} failed, {} hung",
        healthy.failed_frames,
        healthy.hung_clients
    );

    // Pass 2: same workload, one shard killed — by the seeded plan's
    // deterministic arrival index when E7_FAULT_PLAN is set, by the
    // wall-clock timer (~30% in) otherwise.
    let reg = Registry::new();
    let (svc, switches) = start_fleet(&medium, shards, reg.clone(), plan);
    let kill = if plan.is_some() {
        None // the armed device kills itself at the planned arrival
    } else {
        let kill_after = Duration::from_secs_f64((healthy.secs * 0.3).max(0.01));
        Some((switches[shards - 1].clone(), kill_after))
    };
    let degraded = drive(&svc.client(), clients, submissions, rows, kill);
    svc.shutdown();
    let snap = reg.snapshot();
    let degraded_rate = degraded.ok_rows as f64 / degraded.secs.max(1e-9);
    let frac = degraded_rate / healthy_rate.max(1e-9);
    let ideal = (shards - 1) as f64 / shards as f64;
    println!(
        "degraded: {:.0} rows/s ({} rows in {:.2}s, {} failed, {} hung) — \
         {:.2} of healthy (ideal {:.2}), {} failovers",
        degraded_rate,
        degraded.ok_rows,
        degraded.secs,
        degraded.failed_frames,
        degraded.hung_clients,
        frac,
        ideal,
        snap.get("service_failovers").copied().unwrap_or(0.0)
    );

    let mut rec = BTreeMap::new();
    rec.insert("bench".to_string(), Json::Str("e7_loadgen".to_string()));
    rec.insert("clients".to_string(), Json::Num(clients as f64));
    rec.insert("submissions".to_string(), Json::Num(submissions as f64));
    rec.insert("rows".to_string(), Json::Num(rows as f64));
    rec.insert("shards".to_string(), Json::Num(shards as f64));
    rec.insert("healthy_rows_per_s".to_string(), Json::Num(healthy_rate));
    rec.insert("degraded_rows_per_s".to_string(), Json::Num(degraded_rate));
    rec.insert("degraded_frac".to_string(), Json::Num(frac));
    rec.insert("ideal_frac".to_string(), Json::Num(ideal));
    rec.insert(
        "failed_frames".to_string(),
        Json::Num(degraded.failed_frames as f64),
    );
    rec.insert(
        "hung_clients".to_string(),
        Json::Num(degraded.hung_clients as f64),
    );
    rec.insert(
        "failovers".to_string(),
        Json::Num(snap.get("service_failovers").copied().unwrap_or(0.0)),
    );
    rec.insert(
        "kill_schedule".to_string(),
        Json::Str(match plan {
            Some(p) => p.canonical(),
            None => "timer".to_string(),
        }),
    );
    println!("{}", Json::Obj(rec).to_string_compact());

    // The no-hang guarantee is unconditional; the throughput floor is
    // the CI gate (opt-in so local noise never blocks development).
    anyhow::ensure!(
        degraded.hung_clients == 0,
        "{} clients hung waiting for replies",
        degraded.hung_clients
    );
    if let Ok(raw) = std::env::var("E7_DEGRADED_MIN_FRAC") {
        let min: f64 = raw
            .parse()
            .map_err(|e| anyhow::anyhow!("E7_DEGRADED_MIN_FRAC '{raw}': {e}"))?;
        anyhow::ensure!(
            frac >= min,
            "degraded throughput {frac:.2} of healthy, below the {min:.2} floor"
        );
    }
    Ok(())
}
