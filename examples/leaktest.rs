//! RSS-stability probe for the engine hot path (regression guard for the
//! vendored xla_rs.cc input-buffer leak; see runtime/engine.rs).
use litl::runtime::Engine;
use litl::tensor::Tensor;
fn rss_mb() -> f64 {
    let s = std::fs::read_to_string("/proc/self/status").unwrap();
    s.lines()
        .find(|l| l.starts_with("VmRSS"))
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|kb| kb.parse::<f64>().ok())
        .map(|kb| kb / 1024.0)
        .unwrap_or(0.0)
}
fn main() {
    let mut engine = Engine::new("artifacts").unwrap();
    let e = Tensor::zeros(&[32, 10]);
    let b = Tensor::zeros(&[10, 256]);
    for _ in 0..50 {
        let _ = engine.call("project_exact", "small", &[&e, &b, &b]).unwrap();
    }
    let r0 = rss_mb();
    for _ in 0..2000 {
        let _ = engine.call("project_exact", "small", &[&e, &b, &b]).unwrap();
    }
    let grown = rss_mb() - r0;
    println!("RSS growth over 2000 calls: {grown:+.1} MB");
    assert!(grown < 10.0, "engine hot path leaks: {grown} MB / 2000 calls");
    println!("leak guard OK");
}
