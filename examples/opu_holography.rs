//! Inside the photonic co-processor: a guided tour of the optics stack.
//!
//! Walks one error vector through every physical stage of the simulated
//! OPU — SLM encoding, scattering, interference, camera, both
//! demodulators — printing what each stage sees, then sweeps the camera
//! noise to show how optical SNR turns into projection error (the knob
//! behind the paper's 97.6% → 95.8% gap).
//!
//! ```bash
//! cargo run --release --example opu_holography
//! ```

use litl::optics::camera::Camera;
use litl::optics::holography::{demod_fft, demod_quadrature};
use litl::optics::medium::TransmissionMatrix;
use litl::optics::{OpticalOpu, OpuParams};
use litl::tensor::{matmul, Tensor};
use litl::util::rng::Pcg64;
use litl::util::stats::correlation;

fn main() -> anyhow::Result<()> {
    litl::util::logging::init();
    let params = OpuParams::default();
    let d_in = 10usize;
    let modes = 64usize;
    let npix = params.oversample * modes;
    let gain = params.gain_for(d_in);

    println!("=== the simulated OPU, stage by stage ===\n");
    println!("device: {} modes, {} px camera line, carrier π/2 rad/px", modes, npix);
    println!("ADC gain {:.2} intensity/count (auto-ranged for d_in={d_in})\n", gain);

    // Stage 0: a ternary error vector on the SLM (paper Eq. 4).
    let e = Tensor::from_vec(
        &[1, d_in],
        vec![1.0, 0.0, -1.0, 0.0, 0.0, 1.0, 0.0, 0.0, -1.0, 0.0],
    );
    println!("SLM frame (ternary error): {:?}", e.row(0));

    // Stage 1: scattering through the fixed medium -> complex field.
    let medium = TransmissionMatrix::sample(7, d_in, modes);
    let yre = matmul(&e, &medium.b_re);
    let yim = matmul(&e, &medium.b_im);
    println!(
        "\nscattered field (first 6 modes):\n  Re: {:?}\n  Im: {:?}",
        &yre.data()[..6],
        &yim.data()[..6]
    );

    // Stage 2: interference with the tilted reference + camera.
    let camera = Camera::new(npix, params.carrier, params.amp, gain);
    let mut rng = Pcg64::seeded(3);
    let pix = |t: &Tensor| -> Vec<f32> {
        t.data().iter().flat_map(|&v| [v; 4]).collect()
    };
    let mut counts = vec![0.0f32; npix];
    camera.expose(&pix(&yre), &pix(&yim), -1.0, 0.0, &mut rng, &mut counts);
    println!("\ncamera counts, first 4 macropixels (fringes visible as 4-phase cycles):");
    for m in 0..4 {
        println!(
            "  mode {m}: {:?}  (field re={:+.2} im={:+.2})",
            &counts[4 * m..4 * m + 4],
            yre.data()[m],
            yim.data()[m]
        );
    }

    // Stage 3: demodulation, both ways.
    let (q_re, q_im) = demod_quadrature(&counts, modes, params.amp, gain);
    let (f_re, _f_im) = demod_fft(&counts, modes, params.oversample, params.carrier, params.amp, gain);
    let as_f64 = |v: &[f32]| v.iter().map(|&x| x as f64).collect::<Vec<_>>();
    println!("\ndemodulation vs ground truth (noiseless):");
    println!(
        "  quadrature: corr(Re)={:.4}  max|err|={:.4} (ADC lsb = {:.4})",
        correlation(&as_f64(&q_re), &as_f64(yre.data())),
        q_re.iter()
            .zip(yre.data())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max),
        gain / (4.0 * params.amp),
    );
    println!(
        "  fourier side-band: corr(Re)={:.4} (textbook path; macropixel truncation)",
        correlation(&as_f64(&f_re), &as_f64(yre.data()))
    );
    let _ = q_im;

    // Stage 4: the full device under a photon-budget sweep.
    println!("\n=== noise sweep: photons/pixel vs projection error ===");
    println!(
        "{:>10} {:>12} {:>14} {:>12}",
        "n_ph", "read σ", "rel. error", "SNR dB"
    );
    let frames = 64usize;
    let mut e_batch = Tensor::zeros(&[frames, d_in]);
    let mut rng = Pcg64::seeded(5);
    for v in e_batch.data_mut() {
        *v = (rng.next_below(3) as i64 - 1) as f32;
    }
    let exact = matmul(&e_batch, &medium.b_re);
    let sig: f64 = exact.data().iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
    for (n_ph, read_sigma) in [
        (1e9f32, 0.0f32),
        (10_000.0, 0.5),
        (1_000.0, 1.0),
        (100.0, 2.0), // production default (manifest)
        (10.0, 4.0),
        (2.0, 8.0),
    ] {
        let mut opu = OpticalOpu::new(params, medium.clone(), 11);
        opu.set_noise(n_ph, read_sigma);
        let (p1, _) = opu.project(&e_batch)?;
        let err: f64 = p1
            .data()
            .iter()
            .zip(exact.data())
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        println!(
            "{:>10} {:>12} {:>13.2}% {:>12.1}",
            n_ph,
            read_sigma,
            100.0 * err / sig,
            20.0 * (sig / err).log10()
        );
    }
    println!(
        "\nthe E5 bench (cargo bench --bench e5_ablation) maps this SNR axis\n\
         to end-to-end training accuracy."
    );
    Ok(())
}
