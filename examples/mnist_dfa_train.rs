//! End-to-end driver for the paper's §III experiment (E1): trains the
//! 784→1024→1024→10 tanh MLP with all four feedback algorithms and
//! prints the accuracy table next to the paper's numbers, plus the
//! device timing/energy accounting.  Loss curves go to `runs/` as CSV.
//!
//! ```bash
//! cargo run --release --example mnist_dfa_train                  # reduced budget
//! LITL_E1_EPOCHS=10 LITL_E1_TRAIN=60000 \
//!   cargo run --release --example mnist_dfa_train                # paper scale
//! LITL_E1_CONFIG=small cargo run --release --example mnist_dfa_train  # fast smoke
//! ```
//!
//! The recorded run for EXPERIMENTS.md uses the default reduced budget
//! (single CPU core): epochs=2, train=12000, test=2000, hidden=1024.

use litl::config::{Algo, TrainConfig};
use litl::coordinator::{TrainReport, Trainer};
use litl::data;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    litl::util::logging::init();
    let epochs = env_usize("LITL_E1_EPOCHS", 2);
    let train_size = env_usize("LITL_E1_TRAIN", 12_000);
    let test_size = env_usize("LITL_E1_TEST", 2_000);
    let config = std::env::var("LITL_E1_CONFIG").unwrap_or("paper".into());
    let seed = env_usize("LITL_E1_SEED", 42) as u64;

    let ds = data::load_or_synth(seed, train_size, test_size)?;
    println!(
        "E1: {epochs} epochs x {train_size} train / {test_size} test, \
         artifact config '{config}'"
    );

    // The paper's rows: (algo, lr, paper accuracy %).  Optical appears
    // twice: at the paper's lr=0.01 and at 0.001 (our simulated device's
    // noise/task combination prefers the smaller rate at this budget).
    let rows: Vec<(Algo, f32, Option<f64>)> = vec![
        (Algo::Bp, 0.001, None), // implicit BP reference
        (Algo::DfaFloat, 0.001, Some(97.7)),
        (Algo::DfaTernary, 0.001, Some(97.6)),
        (Algo::Optical, 0.01, Some(95.8)),
        (Algo::Optical, 0.001, None),
    ];

    let mut reports: Vec<TrainReport> = Vec::new();
    for (algo, lr, _) in &rows {
        let cfg = TrainConfig {
            artifact_config: config.clone(),
            algo: *algo,
            epochs,
            train_size,
            test_size,
            lr: *lr,
            seed,
            out_dir: Some("runs".into()),
            ..TrainConfig::default()
        };
        log::info!("=== {} (lr={lr}) ===", algo.name());
        let mut trainer = Trainer::new(cfg)?;
        let report = trainer.run(&ds)?;
        trainer.save_checkpoint(&format!("runs/{}_lr{}.ckpt", algo.name(), lr))?;
        reports.push(report);
    }

    println!("\n=== E1: test accuracy (paper §III vs this run) ===");
    println!(
        "{:<14} {:>6} {:>10} {:>11} {:>9} {:>11} {:>9}",
        "algo", "lr", "paper", "measured", "wall s", "OPU sim s", "OPU J"
    );
    for ((algo, lr, paper), rep) in rows.iter().zip(&reports) {
        println!(
            "{:<14} {:>6} {:>10} {:>10.2}% {:>9.1} {:>11.1} {:>9.1}",
            algo.name(),
            lr,
            paper.map(|p| format!("{p:.1}%")).unwrap_or("—".into()),
            rep.final_accuracy_pct(),
            rep.wall_seconds,
            rep.sim_device_seconds,
            rep.device_energy_joules,
        );
    }
    println!(
        "\nnote: dataset is {} (paper used MNIST); the claim under test is\n\
         the ORDERING optical ≤ dfa-ternary ≤ dfa-float ≤ bp and gap scale,\n\
         not absolute accuracy. See DESIGN.md §2 and EXPERIMENTS.md §E1.",
        if std::env::var("LITL_MNIST_DIR").is_ok() {
            "real MNIST"
        } else {
            "synthetic MNIST-like digits"
        }
    );

    let ordering_ok = reports[3].final_eval.accuracy
        <= reports[1].final_eval.accuracy + 0.02
        && reports[2].final_eval.accuracy <= reports[1].final_eval.accuracy + 0.02;
    println!(
        "ordering check: {}",
        if ordering_ok { "PASS" } else { "DIVERGES (see notes)" }
    );
    Ok(())
}
