//! Perspectives scenario: an *ensemble* of networks trained through one
//! shared photonic co-processor.
//!
//! The paper's closing remark — "future tests will involve scaling to
//! even larger networks or ensembles of networks" — is an architecture
//! question: can one OPU serve many concurrent trainers?  This example
//! runs N independent DFA trainers against a single simulated device via
//! the projection service (dynamic frame batching), then reports
//! per-member and majority-vote accuracy plus the device's utilization.
//!
//! ```bash
//! cargo run --release --example ensemble
//! LITL_ENSEMBLE_N=8 cargo run --release --example ensemble
//! ```

use std::sync::{Arc, Mutex};

use litl::coordinator::host::{HostAlgo, HostMlp, HostTrainer};
use litl::coordinator::projector::{NativeOpticalProjector, Projector};
use litl::coordinator::service::{ProjectionService, ServiceConfig};
use litl::coordinator::ProjectionClient;
use litl::data::{self, Split};
use litl::metrics::Registry;
use litl::optics::medium::TransmissionMatrix;
use litl::optics::OpuParams;
use litl::tensor::Tensor;
use litl::util::rng::Pcg64;

/// Projector adapter over a service client (each trainer thread holds
/// one; the physical device lives behind the dispatcher).
struct ServiceProjector {
    client: ProjectionClient,
    modes: usize,
    frames: u64,
}

impl Projector for ServiceProjector {
    fn project(&mut self, frames: &Tensor) -> anyhow::Result<(Tensor, Tensor)> {
        self.frames += frames.rows() as u64;
        self.client.project(frames.clone())
    }
    fn modes(&self) -> usize {
        self.modes
    }
    fn sim_seconds(&self) -> f64 {
        self.frames as f64 / 1500.0
    }
    fn energy_joules(&self) -> f64 {
        self.sim_seconds() * 30.0
    }
    fn kind(&self) -> &'static str {
        "service"
    }
}

fn main() -> anyhow::Result<()> {
    litl::util::logging::init();
    let members: usize = std::env::var("LITL_ENSEMBLE_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);
    let hidden = 128usize;
    let layers = vec![784usize, hidden, hidden, 10];
    let epochs = 5usize;
    let batch = 32usize;
    let train_size = 6_000usize;
    let test_size = 1_000usize;

    let ds = Arc::new(data::load_or_synth(9, train_size, test_size)?);
    println!(
        "ensemble: {members} members (784-{hidden}-{hidden}-10), one shared OPU, \
         {epochs} epochs x {train_size} samples"
    );

    // One physical device for everyone.
    let medium = TransmissionMatrix::sample(77, 10, hidden);
    let device = Box::new(NativeOpticalProjector::new(
        OpuParams::default(),
        medium,
        123,
    ));
    let metrics = Registry::new();
    let svc = ProjectionService::start(
        device,
        10,
        ServiceConfig {
            max_batch: 128,
            queue_depth: 256,
        },
        metrics.clone(),
    );

    let t0 = std::time::Instant::now();
    let results: Arc<Mutex<Vec<(usize, f32, HostMlp)>>> = Arc::new(Mutex::new(Vec::new()));
    let handles: Vec<_> = (0..members)
        .map(|i| {
            let client = svc.client();
            let ds = ds.clone();
            let results = results.clone();
            let layers = layers.clone();
            std::thread::spawn(move || {
                let projector = Box::new(ServiceProjector {
                    client,
                    modes: layers[1],
                    frames: 0,
                });
                let mut tr = HostTrainer::new(
                    1000 + i as u64,
                    &layers,
                    0.001,
                    HostAlgo::DfaTernary { theta: 0.1 },
                    projector,
                );
                let mut rng = Pcg64::new(55, i as u64);
                for _ in 0..epochs {
                    for (x, y) in ds.batches(Split::Train, batch, &mut rng) {
                        tr.step(&x, &y).unwrap();
                    }
                }
                let idxs: Vec<usize> = (0..ds.len(Split::Test)).collect();
                let (tx, ty) = ds.gather(Split::Test, &idxs);
                let acc = tr.mlp.accuracy(&tx, &ty);
                results.lock().unwrap().push((i, acc, tr.mlp.clone()));
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    svc.shutdown();

    let mut results = Arc::try_unwrap(results).ok().unwrap().into_inner().unwrap();
    results.sort_by_key(|(i, _, _)| *i);

    // Majority-vote ensemble accuracy.
    let idxs: Vec<usize> = (0..ds.len(Split::Test)).collect();
    let (tx, ty) = ds.gather(Split::Test, &idxs);
    let mut vote_correct = 0usize;
    let n_test = tx.rows();
    let member_probs: Vec<_> = results.iter().map(|(_, _, m)| m.forward(&tx).probs).collect();
    for r in 0..n_test {
        let mut scores = [0.0f32; 10];
        for probs in &member_probs {
            for c in 0..10 {
                scores[c] += probs.at(r, c);
            }
        }
        let pred = scores
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        let truth = (0..10).find(|&c| ty.at(r, c) > 0.5).unwrap();
        if pred == truth {
            vote_correct += 1;
        }
    }

    println!("\n=== results ===");
    for (i, acc, _) in &results {
        println!("  member {i}: {:.2}%", acc * 100.0);
    }
    println!("  ensemble (soft vote): {:.2}%", 100.0 * vote_correct as f32 / n_test as f32);

    let snap = metrics.snapshot();
    let frames = snap["service_frames"];
    let batches = snap["service_batches"];
    println!("\n=== shared OPU utilization ===");
    println!("  frames projected  : {frames}");
    println!("  device batches    : {batches} (mean occupancy {:.1} frames)", frames / batches);
    println!("  simulated OPU time: {:.1} s @ 1.5 kHz", frames / 1500.0);
    println!("  simulated energy  : {:.1} J @ 30 W", frames / 1500.0 * 30.0);
    println!("  wall time         : {wall:.1} s ({members} trainers, 1 core)");
    Ok(())
}
