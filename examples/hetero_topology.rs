//! Mixed optical/digital weighted fleet from one `Topology` descriptor.
//!
//! Declares 2 simulated OPUs at service weight 2 plus 1 exact digital
//! comparator at weight 1, builds the farm and the shard-aware service
//! from the same value, and drives a host DFA trainer through it.
//! (Doc-style snippet, mirrored by `rust/tests/topology.rs`.)

use litl::config::Partition;
use litl::coordinator::host::{HostAlgo, HostTrainer};
use litl::coordinator::projector::Projector;
use litl::coordinator::service::{ClientProjector, ShardServiceConfig};
use litl::coordinator::topology::Topology;
use litl::metrics::Registry;
use litl::optics::medium::TransmissionMatrix;
use litl::optics::stream::Medium;
use litl::optics::OpuParams;
use litl::tensor::Tensor;
use litl::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    let (err_dim, modes) = (10usize, 32usize);

    // One declarative descriptor: "hetero:opt:2@2+dig:1" also parses.
    let topo = Topology::parse("opt:2@2+dig:1")?
        .with_partition(Partition::Modes);
    println!(
        "topology {} (hash {:016x}): {} shards, weights {:?}",
        topo.shorthand(),
        topo.stable_hash(),
        topo.shard_count(),
        topo.weights()
    );

    // The same medium every projector arm shares (seed-defined).
    let medium = Medium::Dense(TransmissionMatrix::sample(91, err_dim, modes));

    // (a) A farm — one logical projector over the mixed fleet.
    let mut farm = topo.build_farm(OpuParams::default(), &medium, 7, Registry::new())?;
    let mut rng = Pcg64::seeded(1);
    let mut e = Tensor::zeros(&[8, err_dim]);
    for v in e.data_mut() {
        *v = (rng.next_below(3) as i64 - 1) as f32;
    }
    let (p1, _p2) = farm.project(&e)?;
    println!("farm '{}' projected [8, {}]", farm.kind(), p1.cols());

    // (b) A running service — per-shard lanes and workers — feeding a
    // host DFA trainer via the ClientProjector adapter.
    let reg = Registry::new();
    let svc = topo.build_service(
        OpuParams::default(),
        &medium,
        7,
        err_dim,
        ShardServiceConfig {
            partition: Partition::Modes,
            ..Default::default()
        },
        reg.clone(),
    )?;
    let projector = Box::new(ClientProjector::new(svc.client(), modes));
    let mut trainer = HostTrainer::new(
        11,
        &[20, modes, modes, 10],
        0.01,
        HostAlgo::DfaTernary { theta: 0.1 },
        projector,
    );
    for step in 0..20u64 {
        let mut x = Tensor::zeros(&[16, 20]);
        let mut rng = Pcg64::seeded(100 + step);
        for v in x.data_mut() {
            *v = rng.next_f32() * 2.0 - 1.0;
        }
        let mut y = Tensor::zeros(&[16, 10]);
        for r in 0..16 {
            *y.at_mut(r, r % 10) = 1.0;
        }
        let loss = trainer.step(&x, &y)?;
        if step % 5 == 0 {
            println!("step {step}: loss {loss:.4}");
        }
    }
    svc.shutdown();
    println!(
        "fleet slots: {}",
        reg.sum_counters("service_shard", "_slots")
    );
    Ok(())
}
