//! Quickstart: train the paper's MLP with light in the loop, five lines
//! of API.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Uses the `small` build config (784→256→256→10) and a reduced data
//! budget so it finishes in ~a minute on one core.  The full paper-scale
//! run is `examples/mnist_dfa_train.rs`.

use litl::config::{Algo, TrainConfig};
use litl::coordinator::Trainer;
use litl::data;

fn main() -> anyhow::Result<()> {
    litl::util::logging::init();

    // 1. Configure: hybrid optical-DFA training, reduced budget.
    let cfg = TrainConfig {
        artifact_config: "small".into(),
        algo: Algo::Optical,
        epochs: 5,
        train_size: 6_400,
        test_size: 1_000,
        lr: 0.001,
        ..TrainConfig::default()
    };

    // 2. Data: real MNIST if LITL_MNIST_DIR is set, else synthetic digits.
    let ds = data::load_or_synth(cfg.seed, cfg.train_size, cfg.test_size)?;

    // 3. Train: forward + update in XLA, error projection through the
    //    simulated photonic co-processor.
    let mut trainer = Trainer::new(cfg)?;
    let report = trainer.run(&ds)?;

    // 4. Results.
    println!("\n=== quickstart: optical DFA (simulated OPU) ===");
    println!("final test accuracy : {:.2}%", report.final_accuracy_pct());
    println!("parameters          : {}", report.num_params);
    println!("wall time           : {:.1} s", report.wall_seconds);
    println!(
        "simulated OPU time  : {:.1} s ({} frames @ 1.5 kHz)",
        report.sim_device_seconds, report.frames
    );
    println!(
        "simulated OPU energy: {:.1} J ({:.1} mJ / projection)",
        report.device_energy_joules,
        1e3 * report.device_energy_joules / report.frames as f64
    );
    for ep in &report.epochs {
        println!(
            "  epoch {}: loss {:.4}, acc {:.2}%",
            ep.epoch,
            ep.mean_loss,
            ep.eval.unwrap().accuracy * 100.0
        );
    }
    Ok(())
}
