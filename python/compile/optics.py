"""L2 — JAX twin of the OPU optics (the photonic co-processor physics).

The physical pipeline being modeled (paper §II-B, "Off-axis holography"):

1. **SLM encoding** — the ternary error vector ``e ∈ {-1,0,+1}^D`` is
   displayed on a spatial light modulator and carried by a coherent beam.
2. **Scattering** — the beam traverses a diffusive medium whose effect is
   a *fixed* complex Gaussian transmission matrix ``B ∈ C^{D×M}``:
   the field at the camera is ``y = e @ B`` (a random projection "at the
   speed of light").
3. **Off-axis holography** — the camera only measures intensity, so a
   tilted plane-wave reference ``r(p) = A·e^{ikp}`` is superimposed; the
   fringes encode the *linear* field, which is recovered by demodulation.
4. **Camera** — shot noise, read noise, 8-bit ADC.

Design choices (documented in DESIGN.md §2):

* **Complex modes = two real projections.** For ``e`` real,
  ``Re(y) = e @ Re(B)`` and ``Im(y) = e @ Im(B)`` are two independent
  Gaussian random projections — the OPU feeds *both* hidden layers with a
  single frame: ``P₁ = Re(y)``, ``P₂ = Im(y)``.
* **Quadrature demodulation.** With the carrier at k = π/2 rad/pixel and
  4 pixels per macropixel (mode), the intensity at the four pixel phases
  0, π/2, π, 3π/2 of mode ``m`` satisfies ``I₀-I₂ = 4A·Re(y_m)`` and
  ``I₁-I₃ = 4A·Im(y_m)`` — the DC terms ``|y|²+A²`` cancel *exactly*.
  This is the spatial phase-stepping view of off-axis holography; the
  textbook Fourier side-band filter is also implemented (`demod_fft`) and
  the two are shown to agree in `python/tests/test_optics.py`.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from .kernels import camera_intensity, matmul


@dataclasses.dataclass(frozen=True)
class OpuConfig:
    """Physical constants of the simulated OPU.

    These are written into ``artifacts/manifest.json`` and re-read by the
    rust coordinator so both implementations describe the same device.
    """

    oversample: int = 4        # pixels per output mode (quadrature demod)
    carrier: float = np.pi / 2  # reference-beam tilt, rad/pixel
    amp: float = 16.0          # reference amplitude (field units)
    n_ph: float = 100.0        # photon budget scale (shot noise ∝ 1/√n_ph)
    read_sigma: float = 2.0    # camera read noise (intensity units)
    frame_rate_hz: float = 1500.0  # paper: 1.5 kHz
    power_watts: float = 30.0      # paper: ~30 W
    max_modes: int = 100_000       # paper: output dim ~1e5 (off-axis)

    def npix(self, modes: int) -> int:
        return self.oversample * modes

    def gain_for(self, d_in: int) -> float:
        """ADC gain (intensity units per count) auto-ranged to the input.

        A real OPU calibrates camera exposure so the interference pattern
        fills the 8-bit range without saturating.  The field quadratures
        have std ≤ √(d_in/2) for a ternary input of dimension ``d_in``;
        head-room of 4.5σ on top of the reference amplitude keeps
        saturation below ~10⁻⁵ per pixel while using ~250 of 255 counts.
        """
        peak = (self.amp + 4.5 * np.sqrt(d_in / 2.0)) ** 2
        return float(peak / 250.0)


DEFAULT_OPU = OpuConfig()


def make_medium(key, d_in: int, modes: int):
    """Sample the fixed transmission matrix of the diffusive medium.

    Entries are CN(0, 1): re/im ~ N(0, 1/2), so ``E|B_dm|² = 1`` and each
    quadrature of the projection is a standard Gaussian random projection
    scaled by √(nnz(e)/2).
    """
    import jax

    kre, kim = jax.random.split(key)
    scale = np.sqrt(0.5).astype(np.float32)
    b_re = jax.random.normal(kre, (d_in, modes), jnp.float32) * scale
    b_im = jax.random.normal(kim, (d_in, modes), jnp.float32) * scale
    return b_re, b_im


def carrier_tables(cfg: OpuConfig, modes: int):
    """cos/sin of the reference carrier at each pixel, ``[1, Npix]``."""
    p = np.arange(cfg.npix(modes), dtype=np.float64)
    phase = cfg.carrier * p
    return (
        jnp.asarray(np.cos(phase), jnp.float32)[None, :],
        jnp.asarray(np.sin(phase), jnp.float32)[None, :],
    )


def project_exact(e, b_re, b_im):
    """Noiseless digital projection (calibration oracle / GPU baseline).

    Returns ``(P1, P2) = (e @ Re B, e @ Im B)``, each ``[B, M]``.
    """
    return matmul(e, b_re), matmul(e, b_im)


def opu_project(e_t, b_re, b_im, n1, n2, n_ph, read_sigma,
                cfg: OpuConfig = DEFAULT_OPU, cosk=None, sink=None):
    """Full optical pipeline: SLM → scattering → holography → demod.

    Args:
      e_t:   ``[B, D]`` ternary frames (one per sample).
      b_re, b_im: ``[D, M]`` transmission-matrix quadratures.
      n1, n2: ``[B, Npix]`` standard-normal draws (camera noise).
      n_ph, read_sigma: runtime noise levels (scalars).
      cosk, sink: ``[1, Npix]`` carrier tables.  MUST be passed as
        runtime inputs when AOT-lowering: the HLO *text* printer elides
        constants larger than a few dozen elements (``constant({...})``)
        and the rust-side parser reads them back as zeros.  Defaults to
        computing them inline (fine for eager/jit use in-process).

    Returns ``(P1, P2)`` — recovered ``Re(y)``/``Im(y)``, ``[B, M]``.
    """
    bsz, d_in = e_t.shape
    modes = b_re.shape[1]
    os_ = cfg.oversample
    gain = cfg.gain_for(d_in)

    # Scattering: complex field at the camera, one macropixel per mode.
    yre = matmul(e_t, b_re)
    yim = matmul(e_t, b_im)
    yre_pix = jnp.repeat(yre, os_, axis=1)
    yim_pix = jnp.repeat(yim, os_, axis=1)

    if cosk is None or sink is None:
        cosk, sink = carrier_tables(cfg, modes)
    counts = camera_intensity(
        yre_pix, yim_pix, cosk, sink, n1, n2, n_ph, read_sigma,
        amp=cfg.amp, adc_gain=gain,
    )
    return demod_quadrature(counts, cfg, modes, gain)


def demod_quadrature(counts, cfg: OpuConfig, modes: int, gain: float):
    """Spatial phase-stepping demodulation (exact for k=π/2, os=4).

    ``I = |y|² + A² + 2A(Re y·cos kp + Im y·sin kp)`` sampled at pixel
    phases ``0, π/2, π, 3π/2`` gives ``Re y = (I₀-I₂)/4A``,
    ``Im y = (I₁-I₃)/4A`` — DC terms cancel exactly.
    """
    assert cfg.oversample == 4, "quadrature demod requires 4 px/mode"
    i4 = (counts * gain).reshape(counts.shape[0], modes, 4)
    p1 = (i4[:, :, 0] - i4[:, :, 2]) / (4.0 * cfg.amp)
    p2 = (i4[:, :, 1] - i4[:, :, 3]) / (4.0 * cfg.amp)
    return p1, p2


def demod_fft(counts, cfg: OpuConfig, modes: int, gain: float):
    """Textbook off-axis holography: Fourier side-band extraction.

    Multiply the intensity by ``e^{+ikp}`` (shifting the ``y·r̄`` term to
    baseband), low-pass below half the carrier, divide by A, and average
    each macropixel.  Used in tests/examples to validate the quadrature
    shortcut; the hot path uses `demod_quadrature`.
    """
    npix = cfg.npix(modes)
    p = jnp.arange(npix, dtype=jnp.float32)
    mixer = jnp.exp(1j * cfg.carrier * p)[None, :]
    spec = jnp.fft.fft((counts * gain).astype(jnp.complex64) * mixer,
                       axis=1)
    # Low-pass: keep |f| < carrier/2 (in FFT bin units).
    cutoff = int(npix * cfg.carrier / (2 * 2 * np.pi))
    freqs = jnp.fft.fftfreq(npix) * npix
    mask = (jnp.abs(freqs) < cutoff)[None, :]
    base = jnp.fft.ifft(spec * mask, axis=1) / cfg.amp
    per_mode = base.reshape(counts.shape[0], modes, cfg.oversample).mean(-1)
    return jnp.real(per_mode), jnp.imag(per_mode)
