"""AOT lowering: JAX/Pallas → HLO text artifacts + manifest.json.

This is the single point where python runs: ``make artifacts`` invokes it
once, producing ``artifacts/*.hlo.txt`` and ``artifacts/manifest.json``;
the rust coordinator is self-contained afterwards.

Interchange format is **HLO text**, not a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Each lowered entry point is recorded in the manifest with its full input
and output signature (name, shape) plus the OPU physical constants, so
the rust side never has to guess shapes and both sides describe the same
simulated device.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, optics


@dataclasses.dataclass(frozen=True)
class BuildConfig:
    """One (batch, hidden) instantiation of the static-shape artifacts."""

    name: str
    batch: int
    hidden: int
    eval_batch: int

    @property
    def sizes(self):
        return model.layer_sizes(self.hidden)

    @property
    def modes(self):
        # One complex mode feeds one unit of each hidden layer (re/im).
        return self.hidden


CONFIGS = {
    "paper": BuildConfig("paper", batch=128, hidden=1024, eval_batch=500),
    "small": BuildConfig("small", batch=32, hidden=256, eval_batch=200),
}

ERR_DIM = 10  # output classes = optical input dimension


def _spec(*shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def _param_specs(sizes):
    out = []
    for d_in, d_out in zip(sizes[:-1], sizes[1:]):
        out.append(_spec(d_in, d_out))
        out.append(_spec(d_out))
    return out


def _param_names(prefix=""):
    names = []
    for i in (1, 2, 3):
        names += [f"{prefix}w{i}", f"{prefix}b{i}"]
    return names


def entry_points(cfg: BuildConfig, opu: optics.OpuConfig):
    """(name, fn, input_specs, input_names, output_names) per artifact."""
    sizes = cfg.sizes
    b, h = cfg.batch, cfg.hidden
    p_specs = _param_specs(sizes)
    p_names = _param_names()
    m_names = [n.replace("w", "mw").replace("b", "mb") for n in p_names]
    v_names = [n.replace("w", "vw").replace("b", "vb") for n in p_names]
    npix = opu.npix(cfg.modes)

    def fwd_train(*args):
        params, (x, yoh, theta) = args[:6], args[6:]
        return model.fwd_train(params, x, yoh, theta)

    def dfa_apply(*args):
        params, m, v = args[:6], args[6:12], args[12:18]
        t, lr, x, h1, h2, e, p1, p2 = args[18:]
        p, m2, v2 = model.dfa_apply(params, m, v, t, lr, x, h1, h2, e, p1, p2)
        return (*p, *m2, *v2)

    def bp_step(*args):
        params, m, v = args[:6], args[6:12], args[12:18]
        t, lr, x, yoh = args[18:]
        p, m2, v2, loss = model.bp_step(params, m, v, t, lr, x, yoh)
        return (*p, *m2, *v2, loss)

    def dfa_digital_step(*args):
        params, m, v = args[:6], args[6:12], args[12:18]
        t, lr, x, yoh, b_re, b_im, theta = args[18:]
        p, m2, v2, loss = model.dfa_digital_step(
            params, m, v, t, lr, x, yoh, b_re, b_im, theta)
        return (*p, *m2, *v2, loss)

    def eval_batch(*args):
        params, (x, yoh) = args[:6], args[6:]
        return model.eval_batch(params, x, yoh)

    def opu_project(e_t, b_re, b_im, n1, n2, n_ph, read_sigma, cosk, sink):
        # carrier tables are runtime inputs: large constants do not
        # survive the HLO-text interchange (see optics.opu_project).
        return optics.opu_project(e_t, b_re, b_im, n1, n2, n_ph,
                                  read_sigma, opu, cosk, sink)

    def project_exact(e, b_re, b_im):
        return optics.project_exact(e, b_re, b_im)

    def alignment(*args):
        params = args[:6]
        x, yoh, b_re, b_im, theta = args[6:]
        return model.alignment(params, x, yoh, b_re, b_im, theta)

    proj_specs = [_spec(ERR_DIM, cfg.modes)] * 2
    state_specs = p_specs * 3
    state_names = p_names + m_names + v_names
    xyoh = [_spec(b, 784), _spec(b, ERR_DIM)]

    return [
        ("fwd_train", fwd_train,
         p_specs + xyoh + [_spec()],
         p_names + ["x", "yoh", "theta"],
         ["h1", "h2", "e", "e_t", "loss"]),
        ("dfa_apply", dfa_apply,
         state_specs + [_spec(), _spec(), _spec(b, 784), _spec(b, h),
                        _spec(b, h), _spec(b, ERR_DIM), _spec(b, h),
                        _spec(b, h)],
         state_names + ["t", "lr", "x", "h1", "h2", "e", "p1", "p2"],
         state_names),
        ("bp_step", bp_step,
         state_specs + [_spec(), _spec()] + xyoh,
         state_names + ["t", "lr", "x", "yoh"],
         state_names + ["loss"]),
        ("dfa_digital_step", dfa_digital_step,
         state_specs + [_spec(), _spec()] + xyoh + proj_specs + [_spec()],
         state_names + ["t", "lr", "x", "yoh", "b_re", "b_im", "theta"],
         state_names + ["loss"]),
        ("eval_batch", eval_batch,
         p_specs + [_spec(cfg.eval_batch, 784), _spec(cfg.eval_batch, ERR_DIM)],
         p_names + ["x", "yoh"],
         ["correct", "loss"]),
        ("opu_project", opu_project,
         [_spec(b, ERR_DIM)] + proj_specs + [_spec(b, npix), _spec(b, npix),
                                             _spec(), _spec(),
                                             _spec(1, npix), _spec(1, npix)],
         ["e_t", "b_re", "b_im", "n1", "n2", "n_ph", "read_sigma",
          "cosk", "sink"],
         ["p1", "p2"]),
        ("project_exact", project_exact,
         [_spec(b, ERR_DIM)] + proj_specs,
         ["e", "b_re", "b_im"],
         ["p1", "p2"]),
        ("alignment", alignment,
         p_specs + xyoh + proj_specs + [_spec()],
         p_names + ["x", "yoh", "b_re", "b_im", "theta"],
         ["cos1", "cos2"]),
    ]


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_config(cfg: BuildConfig, opu: optics.OpuConfig, out_dir: str,
                 only=None):
    """Lower every entry point of one BuildConfig; returns manifest rows."""
    rows = []
    for name, fn, specs, in_names, out_names in entry_points(cfg, opu):
        if only and name not in only:
            continue
        fname = f"{name}__b{cfg.batch}_h{cfg.hidden}.hlo.txt"
        path = os.path.join(out_dir, fname)
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        if "constant({..." in text:
            raise RuntimeError(
                f"{name}: HLO text contains an elided large constant "
                "(would read back as zeros in the rust runtime) — pass "
                "the offending array as a runtime input instead")
        with open(path, "w") as f:
            f.write(text)
        out_shapes = [
            list(o.shape) for o in lowered.out_info
        ] if hasattr(lowered, "out_info") else None
        rows.append({
            "entry": name,
            "config": cfg.name,
            "file": fname,
            "inputs": [
                {"name": n, "shape": list(s.shape)}
                for n, s in zip(in_names, specs)
            ],
            "outputs": [{"name": n} for n in out_names],
        })
        print(f"  {fname}: {len(text)/1e6:.2f} MB, "
              f"{len(specs)} inputs, {len(out_names)} outputs")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--configs", default="paper,small",
                    help="comma-separated BuildConfig names")
    ap.add_argument("--only", default=None,
                    help="comma-separated entry names to (re)build")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    opu = optics.DEFAULT_OPU
    only = set(args.only.split(",")) if args.only else None

    # Partial rebuilds (--only and/or a subset of --configs) start from
    # the existing manifest so the other entries survive.
    prior = {}
    manifest_path = os.path.join(args.out_dir, "manifest.json")
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            prior = json.load(f)

    manifest = {
        "version": 1,
        "err_dim": ERR_DIM,
        "opu": {
            "oversample": opu.oversample,
            "carrier": opu.carrier,
            "amp": opu.amp,
            "n_ph": opu.n_ph,
            "read_sigma": opu.read_sigma,
            "adc_gain_err": opu.gain_for(ERR_DIM),
            "frame_rate_hz": opu.frame_rate_hz,
            "power_watts": opu.power_watts,
            "max_modes": opu.max_modes,
        },
        "configs": [],
        "artifacts": [],
    }
    for cname in args.configs.split(","):
        cfg = CONFIGS[cname]
        print(f"config {cfg.name}: batch={cfg.batch} hidden={cfg.hidden}")
        manifest["configs"].append({
            "name": cfg.name,
            "batch": cfg.batch,
            "hidden": cfg.hidden,
            "eval_batch": cfg.eval_batch,
            "modes": cfg.modes,
            "layers": list(cfg.sizes),
        })
        manifest["artifacts"] += lower_config(cfg, opu, args.out_dir, only)

    if prior:
        rebuilt = {(a["entry"], a["config"]) for a in manifest["artifacts"]}
        kept = [
            a for a in prior.get("artifacts", [])
            if (a["entry"], a["config"]) not in rebuilt
            and os.path.exists(os.path.join(args.out_dir, a["file"]))
        ]
        manifest["artifacts"] += kept
        built_cfgs = {c["name"] for c in manifest["configs"]}
        manifest["configs"] += [
            c for c in prior.get("configs", []) if c["name"] not in built_cfgs
        ]

    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
