"""L2 — the paper's model and training steps in JAX (build-time only).

The network is the paper's §III configuration: a fully-connected
``784 → H → H → 10`` tanh MLP (H = 1024 in the paper) with softmax
cross-entropy, trained with Adam.  Three trainers are defined:

* **BP** (`bp_step`) — the classical baseline, Eq. 2.  The backward pass
  is written out manually (three matmuls + gates) so that every matmul
  goes through the L1 Pallas kernel rather than autodiff.
* **Digital DFA** (`dfa_digital_step`) — Eq. 3 with the projection
  computed exactly on silicon.  A runtime threshold θ selects the paper's
  float (θ < 0) vs ternary (θ = 0.1) error variants.
* **Hybrid optical DFA** — split across artifacts so the rust coordinator
  can put the *light in the loop*: `fwd_train` produces the error (plus
  its ternarized form), the OPU device performs the projection (either
  the rust-native physics or the `opu_project` artifact from
  `optics.py`), and `dfa_apply` consumes the projected error and applies
  the fused DFA + Adam update.

Conventions: activations are row-major ``[batch, features]``; weights are
``[fan_in, fan_out]`` so a layer is ``h @ W + b``; the "error" is
``e = softmax(logits) - onehot(y)`` (per-sample, *not* batch-averaged —
the 1/B normalization happens inside the update steps so that the
quantities crossing the optical link match the paper's).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import adam_update, dfa_grads, matmul, ternarize

LAYERS = (784, 1024, 1024, 10)  # paper §III; H overridable via aot.py


def layer_sizes(hidden: int):
    """The paper's topology with a configurable hidden width."""
    return (784, hidden, hidden, 10)


def init_params(key, sizes):
    """He-style init: ``W ~ N(0, 1/√fan_in)``, ``b = 0`` (paper-standard)."""
    params = []
    for d_in, d_out in zip(sizes[:-1], sizes[1:]):
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (d_in, d_out), jnp.float32) / jnp.sqrt(d_in)
        params += [w, jnp.zeros((d_out,), jnp.float32)]
    return tuple(params)


def init_opt_state(sizes):
    """Zeroed Adam moments, one (m, v) pair per parameter tensor."""
    shapes = []
    for d_in, d_out in zip(sizes[:-1], sizes[1:]):
        shapes += [(d_in, d_out), (d_out,)]
    m = tuple(jnp.zeros(s, jnp.float32) for s in shapes)
    v = tuple(jnp.zeros(s, jnp.float32) for s in shapes)
    return m, v


def _forward(params, x):
    """Forward pass through the 2-hidden-layer tanh MLP (Eq. 1)."""
    w1, b1, w2, b2, w3, b3 = params
    h1 = jnp.tanh(matmul(x, w1) + b1)
    h2 = jnp.tanh(matmul(h1, w2) + b2)
    logits = matmul(h2, w3) + b3
    return h1, h2, logits


def _loss_err(logits, y_onehot):
    """Softmax CE loss (mean) and per-sample error ``e = p - y``."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    loss = -jnp.mean(jnp.sum(y_onehot * logp, axis=-1))
    e = jnp.exp(logp) - y_onehot
    return loss, e


def fwd_train(params, x, y_onehot, theta):
    """Training-mode forward: activations + error + ternarized error.

    ``theta`` is the Eq. 4 threshold; ``theta < 0`` keeps the float error
    (digital float-DFA mode / diagnostics).  Returns
    ``(h1, h2, e, e_t, loss)``.
    """
    h1, h2, logits = _forward(params, x)
    loss, e = _loss_err(logits, y_onehot)
    e_t = jnp.where(theta >= 0.0, ternarize(e, jnp.abs(theta)), e)
    return h1, h2, e, e_t, loss


def _adam_all(params, grads, m, v, t, lr):
    """Apply the fused Adam kernel to every parameter tensor."""
    new_p, new_m, new_v = [], [], []
    for p, g, mm, vv in zip(params, grads, m, v):
        p2, m2, v2 = adam_update(p, g, mm, vv, t, lr)
        new_p.append(p2)
        new_m.append(m2)
        new_v.append(v2)
    return tuple(new_p), tuple(new_m), tuple(new_v)


def dfa_apply(params, m, v, t, lr, x, h1, h2, e, p1, p2):
    """DFA update (Eq. 3) given *already projected* error signals.

    ``p1, p2`` are the OPU outputs ``B₁e``/``B₂e`` for the two hidden
    layers (real/imaginary quadratures of a single optical frame).  The
    output layer always receives the true error (standard DFA: the last
    layer's feedback IS ``e``).
    """
    bsz = x.shape[0]
    inv_b = 1.0 / bsz
    dw1, db1 = dfa_grads(x, p1 * inv_b, h1)
    dw2, db2 = dfa_grads(h1, p2 * inv_b, h2)
    # Output layer: exact gradient δW₃ = h₂ᵀ e / B (linear head ⇒ gate 1).
    dw3 = matmul(h2.T, e) * inv_b
    db3 = jnp.sum(e, axis=0) * inv_b
    grads = (dw1, db1, dw2, db2, dw3, db3)
    return _adam_all(params, grads, m, v, t, lr)


def _bp_grads(params, x, y_onehot):
    """Manual backprop (Eq. 2) through the 2-hidden-layer MLP.

    Hand-written so each matmul runs on the L1 Pallas kernel (autodiff
    through `pallas_call` is unsupported for this kernel set).
    """
    w1, b1, w2, b2, w3, b3 = params
    h1, h2, logits = _forward(params, x)
    loss, e = _loss_err(logits, y_onehot)
    bsz = x.shape[0]
    d3 = e / bsz
    dw3 = matmul(h2.T, d3)
    db3 = jnp.sum(d3, axis=0)
    d2 = matmul(d3, w3.T) * (1.0 - h2 * h2)
    dw2 = matmul(h1.T, d2)
    db2 = jnp.sum(d2, axis=0)
    d1 = matmul(d2, w2.T) * (1.0 - h1 * h1)
    dw1 = matmul(x.T, d1)
    db1 = jnp.sum(d1, axis=0)
    return (dw1, db1, dw2, db2, dw3, db3), loss


def bp_step(params, m, v, t, lr, x, y_onehot):
    """One fused backprop + Adam step (the paper's implicit BP baseline)."""
    grads, loss = _bp_grads(params, x, y_onehot)
    params, m, v = _adam_all(params, grads, m, v, t, lr)
    return params, m, v, loss


def dfa_digital_step(params, m, v, t, lr, x, y_onehot, b_re, b_im, theta):
    """One fused *digital* DFA + Adam step (paper's GPU comparison rows).

    The projection uses the same transmission-matrix quadratures as the
    optical path (``P₁ = e' @ Re B``, ``P₂ = e' @ Im B``) but computed
    exactly, with ``e' = ternarize(e, θ)`` when ``θ ≥ 0`` else the float
    error.  This makes "optical vs digital" differ *only* by the physics.
    """
    h1, h2, e, e_t, loss = fwd_train(params, x, y_onehot, theta)
    p1 = matmul(e_t, b_re)
    p2 = matmul(e_t, b_im)
    params, m, v = dfa_apply(params, m, v, t, lr, x, h1, h2, e, p1, p2)
    return params, m, v, loss


def eval_batch(params, x, y_onehot):
    """Evaluation: number of correct top-1 predictions + mean CE loss."""
    _, _, logits = _forward(params, x)
    loss, _ = _loss_err(logits, y_onehot)
    pred = jnp.argmax(logits, axis=-1)
    truth = jnp.argmax(y_onehot, axis=-1)
    correct = jnp.sum((pred == truth).astype(jnp.float32))
    return correct, loss


def alignment(params, x, y_onehot, b_re, b_im, theta):
    """E5 diagnostic: cosine of the angle between the DFA update and the
    true (BP) gradient, per layer — the "feedback alignment" quantity.
    """
    grads_bp, _ = _bp_grads(params, x, y_onehot)
    h1, h2, e, e_t, _ = fwd_train(params, x, y_onehot, theta)
    bsz = x.shape[0]
    p1 = matmul(e_t, b_re)
    p2 = matmul(e_t, b_im)
    dw1, _ = dfa_grads(x, p1 / bsz, h1)
    dw2, _ = dfa_grads(h1, p2 / bsz, h2)

    def cos(a, b):
        num = jnp.sum(a * b)
        den = jnp.linalg.norm(a) * jnp.linalg.norm(b) + 1e-12
        return num / den

    return cos(dw1, grads_bp[0]), cos(dw2, grads_bp[2])
