"""Pure-jnp oracles for every Pallas kernel — the CORE correctness signal.

Each function here is the mathematically-obvious implementation of one
kernel in `kernels/`.  pytest + hypothesis sweep randomized shapes and
values and require allclose agreement; the AOT artifacts additionally get
an end-to-end oracle check in `python/tests/test_model.py`.
"""

from __future__ import annotations

import jax.numpy as jnp

BETA1 = 0.9
BETA2 = 0.999
EPS = 1e-8


def matmul(x, y):
    """Plain ``x @ y`` in f32."""
    return jnp.dot(x.astype(jnp.float32), y.astype(jnp.float32))


def dfa_grads(hprev, p, h):
    """DFA layer gradients: ``G = P ⊙ (1 - h²)``; ``δW = hprevᵀG``, ``δb = ΣG``."""
    g = p * (1.0 - h * h)
    dw = hprev.T @ g
    db = jnp.sum(g, axis=0)
    return dw, db


def adam_update(param, grad, m, v, t, lr):
    """Textbook Adam (Kingma & Ba 2015) with bias correction."""
    t = jnp.asarray(t, jnp.float32)
    m2 = BETA1 * m + (1.0 - BETA1) * grad
    v2 = BETA2 * v + (1.0 - BETA2) * grad * grad
    mhat = m2 / (1.0 - BETA1**t)
    vhat = v2 / (1.0 - BETA2**t)
    return param - lr * mhat / (jnp.sqrt(vhat) + EPS), m2, v2


def ternarize(x, threshold):
    """Paper Eq. 4: sign(x) gated on |x| > θ."""
    return jnp.where(x > threshold, 1.0, jnp.where(x < -threshold, -1.0, 0.0))


def camera_intensity(yre, yim, cosk, sink, n1, n2, n_ph, read_sigma, *,
                     amp, adc_gain):
    """Interference + shot/read noise + 8-bit ADC, unfused."""
    fre = yre + amp * cosk
    fim = yim + amp * sink
    intensity = fre * fre + fim * fim
    shot = jnp.sqrt(jnp.maximum(intensity, 0.0) / n_ph) * n1
    noisy = intensity + shot + read_sigma * n2
    return jnp.clip(jnp.round(noisy / adc_gain), 0.0, 255.0)
