"""Pre-validation port of the crate-owned Box-Muller transcendental
kernels (``rust/src/util/mathk.rs``), pure stdlib IEEE-754 doubles.

The authoring container has no Rust toolchain, so the polynomial
designs for ``ln`` and ``sin_cos`` are proven here first and then
transcribed line-for-line into Rust.  Python floats ARE IEEE-754
binary64 with the same round-to-nearest-even semantics, and every
operation below is a single +, -, *, /, sqrt or bit-cast — no fused
multiply-add, no library call inside the kernels — so a passing trial
here is a statement about the *algorithm*, not about any libm.

What is validated (``python/tests/test_boxmuller.py``):

* ``ln_kern`` / ``sin_cos_kern`` stay within 2 ulp of ``math.log`` /
  ``math.sin``/``math.cos`` over the Box-Muller input domain
  (u = k*2^-53, k >= 1: normal doubles only, subnormals excluded by
  construction; x = 2*pi*v in [0, 2*pi)).
* The lane evaluation (each transcendental as its own pass over a
  16-pair batch) is **bitwise identical** to the scalar per-pair walk —
  the property the Rust suite pins against ``fill_normal_scalar``.
* Quadrant boundaries (v near j/4), spare-carry offsets and
  ``advance``-seeked starts reproduce the scalar walk exactly.

Constants are given as IEEE bit patterns (``_f(0x...)``) rather than
decimal literals so the Python and Rust sources can be diffed for
bit-identity by eye.  They are the classic fdlibm/musl coefficients
(Sun Microsystems, freely redistributable) for ``log``, ``__sin`` and
``__cos`` — but the *contract* here is only "deterministic and ~1 ulp":
the crate pins scalar==lane bitwise, never kernel==libm bitwise
(platform libms differ by build; owning the kernels is what makes the
transmission-matrix bits platform-independent).
"""

from __future__ import annotations

import math
import struct

MASK64 = (1 << 64) - 1
MASK128 = (1 << 128) - 1
PCG_MULT = 0x2360ED051FC65DA44385DF649FCCF645

NORMAL_LANE = 16  # Box-Muller pairs per lane batch (rust: NORMAL_LANE)


def _f(bits: int) -> float:
    """f64 from its IEEE-754 bit pattern (rust: ``f64::from_bits``)."""
    return struct.unpack("<d", struct.pack("<Q", bits))[0]


def f64_bits(x: float) -> int:
    """IEEE-754 bit pattern of an f64 (rust: ``f64::to_bits``)."""
    return struct.unpack("<Q", struct.pack("<d", x))[0]


def _from_bits(bits: int) -> float:
    return struct.unpack("<d", struct.pack("<Q", bits))[0]


# --- ln: fdlibm e_log reduction + polynomial, branch-free -------------
#
# x = 2^k * (1+f) with 1+f in [sqrt(2)/2, sqrt(2)); s = f/(2+f);
# log(1+f) = 2s + 2/3 s^3 + ... evaluated as the split even/odd
# polynomial; result assembled through the single general formula
#   dk*ln2_hi - ((hfsq - (s*(hfsq+R) + dk*ln2_lo)) - f)
# fdlibm special-cases k == 0 as f - (hfsq - s*(hfsq+R)), but that is
# bit-equal to the general formula at dk = 0 (IEEE negation symmetry:
# round(0 - (A - f)) == -round(A - f) == round(f - A)), so one
# branch-free expression serves the whole lane.

LN2_HI = _f(0x3FE62E42FEE00000)
LN2_LO = _f(0x3DEA39EF35793C76)
LG1 = _f(0x3FE5555555555593)
LG2 = _f(0x3FD999999997FA04)
LG3 = _f(0x3FD2492494229359)
LG4 = _f(0x3FCC71C51D8E78AF)
LG5 = _f(0x3FC7466496CB03DE)
LG6 = _f(0x3FC39A09D078C69F)
LG7 = _f(0x3FC2F112DF3E5244)


def ln_kern(x: float) -> float:
    """Natural log of a positive *normal* f64 (the Box-Muller uniform
    domain: no zeros, subnormals, infinities or NaNs)."""
    bits = f64_bits(x)
    hx = (bits >> 32) & 0xFFFFFFFF
    lx = bits & 0xFFFFFFFF
    hx = (hx + (0x3FF00000 - 0x3FE6A09E)) & 0xFFFFFFFF
    k = (hx >> 20) - 0x3FF
    hx = (hx & 0x000FFFFF) + 0x3FE6A09E
    m = _from_bits((hx << 32) | lx)  # 1+f in [sqrt(2)/2, sqrt(2))
    f = m - 1.0
    s = f / (2.0 + f)
    dk = float(k)
    z = s * s
    w = z * z
    t1 = w * (LG2 + w * (LG4 + w * LG6))
    t2 = z * (LG1 + w * (LG3 + w * (LG5 + w * LG7)))
    r = t2 + t1
    hfsq = 0.5 * f * f
    return dk * LN2_HI - ((hfsq - (s * (hfsq + r) + dk * LN2_LO)) - f)


# --- sin_cos on [0, 2*pi]: Cody-Waite quadrant reduction + kernels ----
#
# n = nearest multiple of pi/2 (n in 0..4 on this domain); the residual
# y = x - n*pi/2 is carried as a head/tail pair (y0, y1) through the
# Cody-Waite subtraction (n*PIO2_k is exact: the constants' mantissas
# are truncated so a 3-bit integer multiple stays representable), with
# fdlibm's cancellation-depth check adding the 2nd/3rd term pairs when
# x lands close to a quadrant boundary — cos near its zero crossing
# keeps ~1 ulp accuracy instead of losing the tail to the reduction.
# Then musl's branch-free __sin/__cos cores evaluate on |y| <= pi/4 +
# ulp and the quadrant swaps/signs map back.

INVPIO2 = _f(0x3FE45F306DC9C883)
PIO2_1 = _f(0x3FF921FB54400000)
PIO2_1T = _f(0x3DD0B4611A626331)
PIO2_2 = _f(0x3DD0B4611A600000)
PIO2_2T = _f(0x3BA3198A2E037073)
PIO2_3 = _f(0x3BA3198A2E000000)
PIO2_3T = _f(0x397B839A252049C1)

S1 = _f(0xBFC5555555555549)
S2 = _f(0x3F8111111110F8A6)
S3 = _f(0xBF2A01A019C161D5)
S4 = _f(0x3EC71DE357B1FE7D)
S5 = _f(0xBE5AE5E68A2B9CEB)
S6 = _f(0x3DE5D93A5ACFD57C)

C1 = _f(0x3FA555555555554C)
C2 = _f(0xBF56C16C16C15177)
C3 = _f(0x3EFA01A019CB1590)
C4 = _f(0xBE927E4F809C52AD)
C5 = _f(0x3E21EE9EBDB4B1C4)
C6 = _f(0xBDA8FAE9BE8838D4)


def _sin_core(x: float, y: float) -> float:
    """musl __sin, tail path (iy=1) unconditionally: |x| <= pi/4+ulp,
    y the low part of the reduced argument."""
    z = x * x
    w = z * z
    r = S2 + z * (S3 + z * S4) + z * w * (S5 + z * S6)
    v = z * x
    return x - ((z * (0.5 * y - v * r) - y) - v * S1)


def _cos_core(x: float, y: float) -> float:
    """musl __cos (already branch-free): |x| <= pi/4+ulp."""
    z = x * x
    w = z * z
    r = z * (C1 + z * (C2 + z * C3)) + w * w * (C4 + z * (C5 + z * C6))
    hz = 0.5 * z
    w = 1.0 - hz
    return w + (((1.0 - w) - hz) + (z * r - x * y))


def sin_cos_kern(x: float) -> tuple[float, float]:
    """(sin x, cos x) for x in [0, 2*pi] — the Box-Muller phase domain
    (x = 2*pi*v, v in [0, 1))."""
    # Nearest quadrant: truncation of x*(2/pi) + 0.5 (x >= 0), n in 0..4.
    n = int(x * INVPIO2 + 0.5)
    fn = float(n)
    r = x - fn * PIO2_1  # fn*PIO2_1 exact: 33-bit * 3-bit
    w = fn * PIO2_1T  # 1st round good to 85 bits
    y0 = r - w
    # Cancellation check (fdlibm __rem_pio2): when x sits within
    # ~2^-16 of a quadrant boundary the 85-bit reduction has eaten the
    # result's leading bits; refine with the next pi/2 term pair.
    ex = (f64_bits(x) >> 52) & 0x7FF
    if ex - ((f64_bits(y0) >> 52) & 0x7FF) > 16:
        t = r
        w = fn * PIO2_2
        r = t - w
        w = fn * PIO2_2T - ((t - r) - w)
        y0 = r - w  # 2nd round good to 118 bits
        if ex - ((f64_bits(y0) >> 52) & 0x7FF) > 49:
            t = r
            w = fn * PIO2_3
            r = t - w
            w = fn * PIO2_3T - ((t - r) - w)
            y0 = r - w  # 3rd round: 151 bits, covers every double
    y1 = (r - y0) - w
    s = _sin_core(y0, y1)
    c = _cos_core(y0, y1)
    j = n & 3
    if j == 0:
        return s, c
    if j == 1:
        return c, -s
    if j == 2:
        return -s, -c
    return -c, s


# --- PCG-XSL-RR 128/64 + Box-Muller (rust: util/rng.rs) ---------------

TWO_NEG53 = 1.0 / (1 << 53)
TWO_PI = 2.0 * _f(0x400921FB54442D18)  # 2.0 * std::f64::consts::PI


class Pcg64:
    """Line-for-line port of ``litl::util::rng::Pcg64`` (state arith in
    Python ints masked to 128 bits == Rust wrapping u128)."""

    def __init__(self, seed: int, stream: int):
        self.state = 0
        self.inc = ((stream << 1) | 1) & MASK128
        self.spare: float | None = None
        self.next_u64()
        self.state = (self.state + seed) & MASK128
        self.next_u64()

    def advance(self, delta: int) -> None:
        acc_mult, acc_plus = 1, 0
        cur_mult, cur_plus = PCG_MULT, self.inc
        while delta > 0:
            if delta & 1:
                acc_mult = (acc_mult * cur_mult) & MASK128
                acc_plus = (acc_plus * cur_mult + cur_plus) & MASK128
            cur_plus = ((cur_mult + 1) * cur_plus) & MASK128
            cur_mult = (cur_mult * cur_mult) & MASK128
            delta >>= 1
        self.state = (acc_mult * self.state + acc_plus) & MASK128
        self.spare = None

    def next_u64(self) -> int:
        self.state = (self.state * PCG_MULT + self.inc) & MASK128
        xored = ((self.state >> 64) ^ self.state) & MASK64
        rot = self.state >> 122
        return ((xored >> rot) | (xored << ((64 - rot) & 63))) & MASK64

    def next_f64(self) -> float:
        # (u >> 11) has <= 53 bits: the int->float conversion is exact.
        return float(self.next_u64() >> 11) * TWO_NEG53

    def next_normal(self) -> float:
        """Scalar Box-Muller walk through the owned kernels — the
        oracle the lane kernel is pinned against."""
        if self.spare is not None:
            z, self.spare = self.spare, None
            return z
        while True:
            u = self.next_f64()
            if u > 1e-300:
                break
        v = self.next_f64()
        r = math.sqrt(-2.0 * ln_kern(u))
        sin, cos = sin_cos_kern(TWO_PI * v)
        self.spare = r * sin
        return r * cos

    def normal_lane(self) -> list[float]:
        """One 16-pair lane: uniforms drawn interleaved, then each
        transcendental as its own pass — must be bitwise the scalar
        walk (rust: ``Pcg64::normal_lane``)."""
        assert self.spare is None
        saved = self.state
        u = [0.0] * NORMAL_LANE
        v = [0.0] * NORMAL_LANE
        ok = True
        for k in range(NORMAL_LANE):
            u[k] = self.next_f64()
            v[k] = self.next_f64()
            ok = ok and u[k] > 1e-300
        if not ok:
            self.state = saved
            out = []
            for _ in range(NORMAL_LANE):
                out.append(self.next_normal())
                assert self.spare is not None
                out.append(self.spare)
                self.spare = None
            return out
        r = [-2.0 * ln_kern(uk) for uk in u]
        r = [math.sqrt(rk) for rk in r]
        sc = [sin_cos_kern(TWO_PI * vk) for vk in v]
        out = [0.0] * (2 * NORMAL_LANE)
        for k in range(NORMAL_LANE):
            out[2 * k] = r[k] * sc[k][1]
            out[2 * k + 1] = r[k] * sc[k][0]
        return out

    def fill_normal_scalar(self, n: int) -> list[float]:
        return [self.next_normal() for _ in range(n)]

    def fill_normal(self, n: int) -> list[float]:
        """Lane-batched fill (spare consumed first, scalar tail) —
        rust: ``Pcg64::fill_normal``."""
        out: list[float] = []
        if n and self.spare is not None:
            out.append(self.spare)
            self.spare = None
        while n - len(out) >= 2 * NORMAL_LANE:
            out.extend(self.normal_lane())
        while len(out) < n:
            out.append(self.next_normal())
        return out
