"""Shared helpers for the Pallas kernels: block sizing and padding.

TPU MXU tiles are 128x128; VMEM is ~16 MiB per core.  Our model dims
(784, 1024, 10, batch 128) are not all multiples of 128, so every kernel
wrapper pads its operands up to the block grid and slices the result back.
The pad is zeros, which is exact for matmul/outer-product reductions and
for the elementwise kernels (the padded lanes are discarded).
"""

from __future__ import annotations

import jax.numpy as jnp

# Default block edge: two MXU tiles per side (256x256 = 4 MXU tiles per
# grid step).  Perf iteration #1 (EXPERIMENTS.md §Perf): 128-edge tiles
# made every interpret-mode grid step a tiny while-loop iteration — at
# the paper shapes the dfa_apply artifact ran 56+ iterations per matmul.
# 256-edge tiles keep VMEM modest (3 x 256KB) while quartering the grid.
BLOCK = 512

# All pallas_call sites go through interpret mode: real-TPU lowering emits
# a Mosaic custom-call that the CPU PJRT plugin cannot execute.
INTERPRET = True


def round_up(x: int, m: int) -> int:
    """Smallest multiple of ``m`` that is >= ``x``."""
    return ((x + m - 1) // m) * m


def pick_block(dim: int, preferred: int = BLOCK) -> int:
    """Block edge for a dimension: `preferred` when the dim is big
    enough, otherwise the whole (padded-to-128-or-8) dimension in one
    block (a 129..255-wide dim pads to one 256 block rather than
    splitting into 128+pad)."""
    if dim >= preferred:
        return preferred
    if dim > 128:
        return 256
    return max(8, round_up(dim, 8))


def pad2(x: jnp.ndarray, rows: int, cols: int) -> jnp.ndarray:
    """Zero-pad a 2-D array up to ``(rows, cols)``."""
    r, c = x.shape
    if r == rows and c == cols:
        return x
    return jnp.pad(x, ((0, rows - r), (0, cols - c)))
