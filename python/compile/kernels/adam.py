"""Fused Adam-update Pallas kernel (elementwise, VPU-bound).

One kernel invocation updates one parameter tensor given its gradient and
both moment buffers, returning the new ``(param, m, v)`` triple.  The
bias-corrected step uses the timestep ``t`` passed as a ``(1, 1)`` array
(runtime input, so one compiled artifact serves the whole run) while the
hyper-parameters (β₁, β₂, ε) are compile-time constants baked into the
kernel.  The learning rate is a runtime ``(1, 1)`` input because the paper
compares lr=0.01 (optical) against lr=0.001 (digital).

All five streams are tiled with the same BlockSpec so every block update
is a pure VPU fused-multiply chain with zero HBM re-reads.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, pad2, pick_block, round_up

BETA1 = 0.9
BETA2 = 0.999
EPS = 1e-8


def _adam_kernel(p_ref, g_ref, m_ref, v_ref, t_ref, lr_ref,
                 po_ref, mo_ref, vo_ref):
    t = t_ref[0, 0]
    lr = lr_ref[0, 0]
    g = g_ref[...]
    m = BETA1 * m_ref[...] + (1.0 - BETA1) * g
    v = BETA2 * v_ref[...] + (1.0 - BETA2) * g * g
    # Bias correction: 1 - β^t with a float t (t >= 1).
    bc1 = 1.0 - jnp.power(BETA1, t)
    bc2 = 1.0 - jnp.power(BETA2, t)
    mhat = m / bc1
    vhat = v / bc2
    po_ref[...] = p_ref[...] - lr * mhat / (jnp.sqrt(vhat) + EPS)
    mo_ref[...] = m
    vo_ref[...] = v


@functools.partial(jax.jit, static_argnames=("br", "bc"))
def _adam_raw(p, g, m, v, t, lr, *, br: int, bc: int):
    rows, cols = p.shape
    grid = (rows // br, cols // bc)
    tile = pl.BlockSpec((br, bc), lambda i, j: (i, j))
    scalar = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    shape = jax.ShapeDtypeStruct((rows, cols), jnp.float32)
    return pl.pallas_call(
        _adam_kernel,
        grid=grid,
        in_specs=[tile, tile, tile, tile, scalar, scalar],
        out_specs=[tile, tile, tile],
        out_shape=[shape, shape, shape],
        interpret=INTERPRET,
    )(p, g, m, v, t, lr)


def adam_update(param, grad, m, v, t, lr):
    """Adam step for one parameter tensor of any rank.

    ``t`` and ``lr`` are scalars (or 0-d arrays).  Returns
    ``(param', m', v')`` with the same shape as ``param``.
    """
    shape = param.shape
    flat = int(param.size)
    # Lay the flat parameter out as a [rows, 1024] matrix: wide VPU lanes
    # mean few grid steps (perf iteration #1 — a 1M-param tensor is an
    # (8192 x 128) = 64-step grid at 128 lanes but only 8 steps at 1024).
    cols = 2048 if flat >= 2048 else pick_block(flat)
    rows = round_up((flat + cols - 1) // cols, 8)
    padded = rows * cols

    def prep(x):
        x = jnp.ravel(x).astype(jnp.float32)
        x = jnp.pad(x, (0, padded - flat))
        return x.reshape(rows, cols)

    t_arr = jnp.asarray(t, jnp.float32).reshape(1, 1)
    lr_arr = jnp.asarray(lr, jnp.float32).reshape(1, 1)
    br = pick_block(rows)
    rows_p = round_up(rows, br)
    args = [pad2(prep(x), rows_p, cols) for x in (param, grad, m, v)]
    po, mo, vo = _adam_raw(*args, t_arr, lr_arr, br=br, bc=cols)

    def unprep(x):
        return jnp.ravel(x)[:flat].reshape(shape)

    return unprep(po), unprep(mo), unprep(vo)
