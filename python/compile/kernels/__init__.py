"""L1 — Pallas kernels (interpret=True) for the light-in-the-loop stack.

Every kernel here is the compute hot-spot of one stage of the hybrid
optical-DFA training pipeline, and has a pure-jnp oracle in `ref.py`
against which pytest/hypothesis validate it bit-for-tolerance.

Kernels are written TPU-idiomatically (MXU-sized blocks, VMEM-resident
tiles, fused elementwise gates) but lowered with ``interpret=True`` so the
resulting HLO runs on any PJRT backend, including the rust CPU client on
the request path.  See DESIGN.md §Hardware-Adaptation.
"""

from .matmul import matmul, matmul_pallas_raw
from .dfa_update import dfa_grads
from .adam import adam_update
from .ternary import ternarize
from .intensity import camera_intensity

__all__ = [
    "matmul",
    "matmul_pallas_raw",
    "dfa_grads",
    "adam_update",
    "ternarize",
    "camera_intensity",
]
