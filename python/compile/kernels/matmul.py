"""Tiled matmul Pallas kernel — the random-projection hot-spot.

On the physical OPU the projection ``B @ e`` is performed by light
scattering and is O(1) in the matrix size.  On the digital baseline (and
inside the optics twin, which needs the *field* before the camera) it is a
matmul whose operand ``B`` is far too large to hold on-chip — exactly the
regime TPU Pallas is built for: stream HBM->VMEM block-by-block via
BlockSpec, accumulate in a VMEM-resident output tile on the MXU.

Grid layout: ``(M/bm, N/bn, K/bk)`` with the K axis innermost so each
``(i, j)`` output tile stays resident in VMEM across the whole reduction
(`o_ref` is revision-accumulated; zeroed when ``k == 0``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, pad2, pick_block, round_up


def _mm_kernel(x_ref, y_ref, o_ref):
    # K is the innermost grid axis: zero the VMEM accumulator on the first
    # K-step, then accumulate one MXU tile-product per step.
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul_pallas_raw(x, y, *, bm: int, bn: int, bk: int):
    """Blocked ``x @ y`` for pre-padded operands (shapes divide blocks)."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, (x.shape, y.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=INTERPRET,
    )(x, y)


def matmul(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """``x @ y`` with automatic padding to the block grid.

    Zero padding is exact for a sum reduction; the result is sliced back
    to the true ``(M, N)``.
    """
    m, k = x.shape
    _, n = y.shape
    bm, bn, bk = pick_block(m), pick_block(n), pick_block(k)
    mp, np_, kp = round_up(m, bm), round_up(n, bn), round_up(k, bk)
    xp = pad2(x.astype(jnp.float32), mp, kp)
    yp = pad2(y.astype(jnp.float32), kp, np_)
    out = matmul_pallas_raw(xp, yp, bm=bm, bn=bn, bk=bk)
    return out[:m, :n]
