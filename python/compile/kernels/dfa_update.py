"""Fused DFA weight-gradient Pallas kernel.

The DFA update for a hidden layer is (paper Eq. 3, transposed to our
row-major ``h @ W`` convention)::

    G  = P ⊙ f'(a)          # P = B e, the (optically) projected error
    δW = h_prev^T @ G        # [fan_in, units]
    δb = Σ_batch G           # [units]

with ``f = tanh`` so ``f'(a) = 1 - h²`` (computed from the activation
``h = tanh(a)``, saving the pre-activation round-trip).

Fusing the gate into the outer-product kernel means the gated error ``G``
never exists in HBM — each ``(bk × bn)`` tile of ``P`` and ``h`` is gated
in VMEM registers immediately before feeding the MXU.  The bias gradient
is accumulated in the same pass (on the ``i == 0`` column stripe so each
``(k, j)`` tile contributes exactly once).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, pad2, pick_block, round_up


def _dfa_kernel(hprev_ref, p_ref, h_ref, dw_ref, db_ref):
    i = pl.program_id(0)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init_dw():
        dw_ref[...] = jnp.zeros_like(dw_ref)

    # Gate in-register: G = P * (1 - h^2)  (tanh derivative).
    g = p_ref[...] * (1.0 - h_ref[...] * h_ref[...])
    dw_ref[...] += jnp.dot(
        hprev_ref[...].T, g, preferred_element_type=jnp.float32
    )

    # Bias gradient: each (k, j) pair must contribute once, so only the
    # i == 0 stripe of the grid accumulates it.
    @pl.when((i == 0) & (k == 0))
    def _init_db():
        db_ref[...] = jnp.zeros_like(db_ref)

    @pl.when(i == 0)
    def _acc_db():
        db_ref[...] += jnp.sum(g, axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("bi", "bn", "bk"))
def _dfa_raw(hprev, p, h, *, bi: int, bn: int, bk: int):
    b, fan_in = hprev.shape
    _, units = p.shape
    grid = (fan_in // bi, units // bn, b // bk)
    return pl.pallas_call(
        _dfa_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, bi), lambda i, j, k: (k, i)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=[
            pl.BlockSpec((bi, bn), lambda i, j, k: (i, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((fan_in, units), jnp.float32),
            jax.ShapeDtypeStruct((1, units), jnp.float32),
        ],
        interpret=INTERPRET,
    )(hprev, p, h)


def dfa_grads(hprev: jnp.ndarray, p: jnp.ndarray, h: jnp.ndarray):
    """``(δW, δb)`` for one hidden layer.

    Args:
      hprev: ``[B, fan_in]`` upstream activations (``h_{i-1}``).
      p:     ``[B, units]`` projected error ``B_i e`` (from the OPU).
      h:     ``[B, units]`` this layer's tanh activations.

    Returns:
      ``δW [fan_in, units]``, ``δb [units]`` — *gradients* (caller negates
      / feeds the optimizer).
    """
    b, fan_in = hprev.shape
    _, units = p.shape
    bi, bn, bk = pick_block(fan_in), pick_block(units), pick_block(b)
    fp, up, bp_ = round_up(fan_in, bi), round_up(units, bn), round_up(b, bk)
    hprev_p = pad2(hprev.astype(jnp.float32), bp_, fp)
    p_p = pad2(p.astype(jnp.float32), bp_, up)
    h_p = pad2(h.astype(jnp.float32), bp_, up)
    dw, db = _dfa_raw(hprev_p, p_p, h_p, bi=bi, bn=bn, bk=bk)
    return dw[:fan_in, :units], db[0, :units]
