"""Ternary error quantization Pallas kernel (paper Eq. 4).

The OPU's input device (a binary/ternary DMD-backed SLM) cannot display
float values, so the error vector is quantized::

    f(x) =  1   if x >  θ
            0   if -θ < x < θ
           -1   if x < -θ

with θ = 0.1 in the paper.  θ is a runtime ``(1, 1)`` input so the E5
threshold-sweep ablation reuses one compiled artifact.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, pad2, pick_block, round_up


def _ternary_kernel(x_ref, th_ref, o_ref):
    x = x_ref[...]
    th = th_ref[0, 0]
    o_ref[...] = jnp.where(x > th, 1.0, jnp.where(x < -th, -1.0, 0.0))


@functools.partial(jax.jit, static_argnames=("br", "bc"))
def _ternary_raw(x, th, *, br: int, bc: int):
    rows, cols = x.shape
    grid = (rows // br, cols // bc)
    tile = pl.BlockSpec((br, bc), lambda i, j: (i, j))
    scalar = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    return pl.pallas_call(
        _ternary_kernel,
        grid=grid,
        in_specs=[tile, scalar],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=INTERPRET,
    )(x, th)


def ternarize(x: jnp.ndarray, threshold) -> jnp.ndarray:
    """Eq. 4 quantization of a ``[B, D]`` error matrix to {-1, 0, +1}."""
    b, d = x.shape
    br, bc = pick_block(b), pick_block(d)
    bp_, dp = round_up(b, br), round_up(d, bc)
    xp = pad2(x.astype(jnp.float32), bp_, dp)
    th = jnp.asarray(threshold, jnp.float32).reshape(1, 1)
    return _ternary_raw(xp, th, br=br, bc=bc)[:b, :d]
