"""Camera-plane intensity formation Pallas kernel (optics twin hot loop).

Models what the OPU's camera sees for one frame: the signal field
``y(p)`` (the scattered beam carrying ``B e``, mapped onto pixels by the
macropixel layout) interferes with a tilted plane-wave reference
``r(p) = A·e^{i k p}``, and the sensor records::

    I(p)  = |y(p) + r(p)|²
    I'(p) = I + √(I / n_ph)·ξ₁ + σ_r·ξ₂      (shot + read noise)
    ADC   = clip(round(I' / gain), 0, 255)    (8-bit quantization)

Everything is elementwise per pixel, so the whole physics chain fuses into
one VPU pass: the noisy quantized frame never exists as more than one
VMEM tile at a time.  The Gaussian draws ξ₁, ξ₂ are *inputs* (the rust
coordinator owns the RNG so frames are reproducible across hosts), and the
noise levels ``n_ph`` / ``σ_r`` are runtime scalars so the E5 noise-sweep
ablation reuses a single compiled artifact.

Compile-time constants: reference amplitude ``A`` and ADC gain — geometric
properties of the simulated device, fixed per artifact (they also enter
the demodulation arithmetic, see ``optics.py``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, pad2, pick_block, round_up


def _intensity_kernel(yre_ref, yim_ref, cosk_ref, sink_ref, n1_ref, n2_ref,
                      nph_ref, sigr_ref, o_ref, *, amp, adc_gain):
    n_ph = nph_ref[0, 0]
    read_sigma = sigr_ref[0, 0]
    fre = yre_ref[...] + amp * cosk_ref[...]
    fim = yim_ref[...] + amp * sink_ref[...]
    intensity = fre * fre + fim * fim
    shot = jnp.sqrt(jnp.maximum(intensity, 0.0) / n_ph) * n1_ref[...]
    noisy = intensity + shot + read_sigma * n2_ref[...]
    counts = jnp.clip(jnp.round(noisy / adc_gain), 0.0, 255.0)
    o_ref[...] = counts


@functools.partial(jax.jit, static_argnames=("br", "bc", "amp", "adc_gain"))
def _intensity_raw(yre, yim, cosk, sink, n1, n2, n_ph, read_sigma, *,
                   br, bc, amp, adc_gain):
    rows, cols = yre.shape
    grid = (rows // br, cols // bc)
    tile = pl.BlockSpec((br, bc), lambda i, j: (i, j))
    carrier = pl.BlockSpec((1, bc), lambda i, j: (0, j))
    scalar = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    kern = functools.partial(_intensity_kernel, amp=amp, adc_gain=adc_gain)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[tile, tile, carrier, carrier, tile, tile, scalar, scalar],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=INTERPRET,
    )(yre, yim, cosk, sink, n1, n2, n_ph, read_sigma)


def camera_intensity(yre, yim, cosk, sink, n1, n2, n_ph, read_sigma, *,
                     amp, adc_gain):
    """Quantized camera counts for a batch of frames.

    Args:
      yre, yim: ``[B, Npix]`` signal field at the camera (pixel-mapped).
      cosk, sink: ``[1, Npix]`` reference-carrier phases (cos kx, sin kx).
      n1, n2:  ``[B, Npix]`` standard-normal draws (shot / read noise).
      n_ph, read_sigma: runtime noise levels (scalars / 0-d arrays).
      amp, adc_gain: device geometry constants (python floats).

    Returns ``[B, Npix]`` float32 ADC counts in [0, 255].
    """
    b, npix = yre.shape
    br, bc = pick_block(b), pick_block(npix)
    bp_, pp = round_up(b, br), round_up(npix, bc)
    yre_p = pad2(yre.astype(jnp.float32), bp_, pp)
    yim_p = pad2(yim.astype(jnp.float32), bp_, pp)
    cosk_p = pad2(jnp.asarray(cosk, jnp.float32).reshape(1, npix), 1, pp)
    sink_p = pad2(jnp.asarray(sink, jnp.float32).reshape(1, npix), 1, pp)
    n1_p = pad2(jnp.asarray(n1, jnp.float32), bp_, pp)
    n2_p = pad2(jnp.asarray(n2, jnp.float32), bp_, pp)
    nph = jnp.asarray(n_ph, jnp.float32).reshape(1, 1)
    sigr = jnp.asarray(read_sigma, jnp.float32).reshape(1, 1)
    out = _intensity_raw(
        yre_p, yim_p, cosk_p, sink_p, n1_p, n2_p, nph, sigr,
        br=br, bc=bc, amp=float(amp), adc_gain=float(adc_gain),
    )
    return out[:b, :npix]
