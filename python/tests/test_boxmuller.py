"""Validation harness for the Box-Muller transcendental kernel design
(``compile/kernels/boxmuller.py``) — the Python side of PR-6's
"pre-validate, then transcribe to Rust" workflow.

Stdlib-only (no jax/numpy): runnable in the authoring container.  Run
directly (``python3 python/tests/test_boxmuller.py``) or under pytest.
"""

from __future__ import annotations

import math
import os
import random
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from compile.kernels.boxmuller import (  # noqa: E402
    NORMAL_LANE,
    TWO_PI,
    Pcg64,
    f64_bits,
    ln_kern,
    sin_cos_kern,
)

TRIALS = 400  # >= 300 randomized trials per the PR-6 acceptance bar


def ulp_diff(a: float, b: float) -> int:
    """Distance in representable doubles (same-sign finite operands)."""
    ia, ib = f64_bits(a), f64_bits(b)
    # Map negatives onto a monotone integer line.
    if ia >> 63:
        ia = (1 << 63) - (ia & ~(1 << 63))
    if ib >> 63:
        ib = (1 << 63) - (ib & ~(1 << 63))
    return abs(ia - ib)


def test_ln_kern_accuracy_over_the_uniform_domain():
    rng = random.Random(0xE6)
    worst = 0
    cases = []
    # Randomized: u = k * 2^-53, k in [1, 2^53) — exactly next_f64's range.
    for _ in range(TRIALS):
        k = rng.randrange(1, 1 << 53)
        cases.append(k * 2.0**-53)
    # Edges: smallest/largest uniforms, values pinning the reduction
    # (near 1.0 from below, near sqrt(2)/2 where f changes sign, exact
    # powers of two where f == 0).
    cases += [2.0**-53, 1.0 - 2.0**-53, 0.5, 0.25, 2.0**-52, 2.0**-30]
    sqrt_half = math.sqrt(0.5)
    for bump in range(-4, 5):
        cases.append(max(2.0**-53, math.nextafter(sqrt_half, bump * 1.0)))
    for u in cases:
        d = ulp_diff(ln_kern(u), math.log(u))
        worst = max(worst, d)
        assert d <= 2, f"ln({u!r}): {d} ulp from libm"
    assert worst <= 2


def test_sin_cos_kern_accuracy_and_quadrant_boundaries():
    rng = random.Random(0x51)
    cases = [rng.random() for _ in range(TRIALS)]
    # Quadrant boundaries: v near j/4 (x = 2*pi*v near j*pi/2), from
    # both sides, including v = 0 and v -> 1 (x -> 2*pi).
    for j in range(5):
        base = j / 4.0
        for bump in (-3, -2, -1, 0, 1, 2, 3):
            v = base
            for _ in range(abs(bump)):
                v = math.nextafter(v, base + (1 if bump > 0 else -1))
            if 0.0 <= v < 1.0:
                cases.append(v)
    cases += [0.0, 2.0**-53, 1.0 - 2.0**-53]
    worst = 0
    for v in cases:
        x = TWO_PI * v
        s, c = sin_cos_kern(x)
        ds = ulp_diff(s, math.sin(x))
        dc = ulp_diff(c, math.cos(x))
        worst = max(worst, ds, dc)
        assert ds <= 2 and dc <= 2, f"sin_cos({v!r}): {ds}/{dc} ulp"
        # The pair is a unit phasor to float accuracy.
        assert abs(s * s + c * c - 1.0) < 1e-15
    assert worst <= 2


def test_lane_kernel_is_bitwise_the_scalar_walk():
    rng = random.Random(0xBEEF)
    for trial in range(TRIALS):
        seed = rng.randrange(1 << 64)
        stream = rng.randrange(1 << 64)
        pair_offset = rng.randrange(6000)
        scalar = Pcg64(seed, stream)
        lane = Pcg64(seed, stream)
        scalar.advance(2 * pair_offset)
        lane.advance(2 * pair_offset)
        for n in (rng.randrange(0, 4 * NORMAL_LANE + 3) for _ in range(3)):
            a = scalar.fill_normal_scalar(n)
            b = lane.fill_normal(n)
            bits_a = [f64_bits(x) for x in a]
            bits_b = [f64_bits(x) for x in b]
            assert bits_a == bits_b, f"trial {trial} n {n}"
        # Terminal state agrees, spare included.
        assert f64_bits(scalar.next_normal()) == f64_bits(lane.next_normal())


def test_spare_carry_and_odd_lengths():
    scalar = Pcg64(77, 3)
    lane = Pcg64(77, 3)
    for n in (33, 1, 2 * NORMAL_LANE + 1, 7, 2 * NORMAL_LANE, 0, 5):
        a = scalar.fill_normal_scalar(n)
        b = lane.fill_normal(n)
        assert [f64_bits(x) for x in a] == [f64_bits(x) for x in b], f"n {n}"


def test_extreme_uniform_is_finite_and_accurate():
    # The smallest admissible uniform drives the largest radius the
    # kernel ever sees: r = sqrt(-2 ln 2^-53) ~ 8.57.  No overflow, no
    # subnormals, still 2-ulp accurate.
    u = 2.0**-53
    r_kern = math.sqrt(-2.0 * ln_kern(u))
    r_libm = math.sqrt(-2.0 * math.log(u))
    assert math.isfinite(r_kern)
    assert ulp_diff(r_kern, r_libm) <= 2


def _main() -> int:
    tests = [(k, v) for k, v in sorted(globals().items()) if k.startswith("test_")]
    failed = 0
    for name, fn in tests:
        try:
            fn()
            print(f"PASS {name}")
        except AssertionError as e:
            failed += 1
            print(f"FAIL {name}: {e}")
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(_main())
