"""L2 model invariants + training smoke tests (build-time oracle).

Validates the exact math the AOT artifacts will execute: BP gradients
against jax autodiff of a pure-jnp twin, DFA/BP agreement on the output
layer, and short-horizon learning on a synthetic separable task for all
three trainers (BP, digital DFA, optical DFA with simulated physics).
"""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model, optics

SIZES = (20, 32, 32, 10)  # miniature topology, same structure as paper's


_PROTO = np.random.default_rng(1234).normal(size=(10, 20)).astype(np.float32)


def _data(seed, b=64, d=20, classes=10):
    """Linearly-separable-ish synthetic task: class = argmax of a *fixed*
    random linear map (same task every step, fresh samples per seed)."""
    r = np.random.default_rng(seed)
    x = r.normal(size=(b, d)).astype(np.float32)
    y = np.argmax(x @ _PROTO[:classes, :d].T, axis=1)
    yoh = np.eye(classes, dtype=np.float32)[y]
    return jnp.asarray(x), jnp.asarray(yoh)


def _init(seed=0):
    params = model.init_params(jax.random.PRNGKey(seed), SIZES)
    m, v = model.init_opt_state(SIZES)
    return params, m, v


def _pure_loss(params, x, yoh):
    w1, b1, w2, b2, w3, b3 = params
    h1 = jnp.tanh(x @ w1 + b1)
    h2 = jnp.tanh(h1 @ w2 + b2)
    logits = h2 @ w3 + b3
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.sum(yoh * logp, axis=-1))


class TestGradients:
    def test_bp_matches_autodiff(self):
        """Manual Pallas backprop == jax.grad of the pure-jnp twin."""
        params, _, _ = _init()
        x, yoh = _data(0)
        grads, loss = model._bp_grads(params, x, yoh)
        auto = jax.grad(_pure_loss)(params, x, yoh)
        loss2 = _pure_loss(params, x, yoh)
        np.testing.assert_allclose(loss, loss2, rtol=1e-5)
        for g, a in zip(grads, auto):
            np.testing.assert_allclose(g, a, rtol=5e-4, atol=1e-5)

    def test_dfa_output_layer_equals_bp(self):
        """DFA trains the last layer with the TRUE gradient."""
        params, m, v = _init()
        x, yoh = _data(1)
        h1, h2, e, e_t, _ = model.fwd_train(params, x, yoh, -1.0)
        bre, bim = optics.make_medium(jax.random.PRNGKey(9), 10, SIZES[1])
        p1 = model.matmul(e_t, bre)
        p2 = model.matmul(e_t, bim)
        pd, md, vd = model.dfa_apply(params, m, v, 1.0, 0.01,
                                     x, h1, h2, e, p1, p2)
        pb, mb, vb, _ = model.bp_step(params, m, v, 1.0, 0.01, x, yoh)
        # last-layer weight and bias identical between DFA and BP
        np.testing.assert_allclose(pd[4], pb[4], rtol=1e-4, atol=1e-6)
        np.testing.assert_allclose(pd[5], pb[5], rtol=1e-4, atol=1e-6)

    def test_fwd_train_error_is_probs_minus_onehot(self):
        params, _, _ = _init()
        x, yoh = _data(2)
        _, _, e, _, _ = model.fwd_train(params, x, yoh, -1.0)
        # rows of e sum to zero (softmax sums to 1, onehot sums to 1)
        np.testing.assert_allclose(np.asarray(e).sum(1), 0.0, atol=1e-5)

    def test_theta_negative_keeps_float_error(self):
        params, _, _ = _init()
        x, yoh = _data(3)
        _, _, e, e_t, _ = model.fwd_train(params, x, yoh, -1.0)
        np.testing.assert_allclose(e, e_t)

    def test_theta_positive_ternarizes(self):
        params, _, _ = _init()
        x, yoh = _data(4)
        _, _, _, e_t, _ = model.fwd_train(params, x, yoh, 0.1)
        vals = set(np.unique(np.asarray(e_t)))
        assert vals.issubset({-1.0, 0.0, 1.0})


class TestLearning:
    def _run(self, step_fn, steps=60):
        params, m, v = _init(1)
        losses = []
        for t in range(1, steps + 1):
            x, yoh = _data(100 + t)
            params, m, v, loss = step_fn(params, m, v, float(t), x, yoh)
            losses.append(float(loss))
        return losses

    def test_bp_learns(self):
        losses = self._run(
            lambda p, m, v, t, x, y: model.bp_step(p, m, v, t, 0.01, x, y))
        assert np.mean(losses[-10:]) < 0.5 * np.mean(losses[:5])

    def test_digital_dfa_float_learns(self):
        bre, bim = optics.make_medium(jax.random.PRNGKey(5), 10, SIZES[1])

        def step(p, m, v, t, x, y):
            return model.dfa_digital_step(p, m, v, t, 0.01, x, y,
                                          bre, bim, -1.0)

        losses = self._run(step)
        assert np.mean(losses[-10:]) < 0.7 * np.mean(losses[:5])

    def test_digital_dfa_ternary_learns(self):
        bre, bim = optics.make_medium(jax.random.PRNGKey(6), 10, SIZES[1])

        def step(p, m, v, t, x, y):
            return model.dfa_digital_step(p, m, v, t, 0.01, x, y,
                                          bre, bim, 0.1)

        losses = self._run(step)
        assert np.mean(losses[-10:]) < 0.8 * np.mean(losses[:5])

    def test_optical_dfa_learns(self):
        """Full light-in-the-loop: simulated OPU physics in the loop."""
        cfg = optics.DEFAULT_OPU
        modes = SIZES[1]
        bre, bim = optics.make_medium(jax.random.PRNGKey(7), 10, modes)
        rng = np.random.default_rng(0)

        def step(p, m, v, t, x, y):
            h1, h2, e, e_t, loss = model.fwd_train(p, x, y, 0.1)
            b = x.shape[0]
            n1 = rng.normal(size=(b, cfg.npix(modes))).astype(np.float32)
            n2 = rng.normal(size=(b, cfg.npix(modes))).astype(np.float32)
            p1, p2 = optics.opu_project(e_t, bre, bim, n1, n2,
                                        cfg.n_ph, cfg.read_sigma, cfg)
            p2_, m2, v2 = model.dfa_apply(p, m, v, t, 0.01, x, h1, h2,
                                          e, p1, p2)
            return p2_, m2, v2, loss

        losses = self._run(step)
        assert np.mean(losses[-10:]) < 0.8 * np.mean(losses[:5])


class TestEvalAndAlignment:
    def test_eval_counts(self):
        params, _, _ = _init()
        x, yoh = _data(5, b=50)
        correct, loss = model.eval_batch(params, x, yoh)
        assert 0 <= float(correct) <= 50
        assert float(loss) > 0

    def test_alignment_positive_after_training(self):
        """DFA's core phenomenon: updates align with the true gradient."""
        bre, bim = optics.make_medium(jax.random.PRNGKey(8), 10, SIZES[1])
        params, m, v = _init(2)
        for t in range(1, 40):
            x, yoh = _data(200 + t)
            params, m, v, _ = model.dfa_digital_step(
                params, m, v, float(t), 0.01, x, yoh, bre, bim, -1.0)
        x, yoh = _data(999)
        c1, c2 = model.alignment(params, x, yoh, bre, bim, -1.0)
        assert float(c1) > 0.1  # alignment emerges
        assert float(c2) > 0.1
