"""Optics twin: the simulated OPU recovers the true linear projection.

These validate the physics substitution documented in DESIGN.md §2: the
quadrature off-axis holography demodulation is exact up to ADC/noise, the
noise scaling behaves as modeled, and the re/im quadratures give two
independent random projections.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import numpy as np

from compile import optics

SETTINGS = dict(deadline=None, max_examples=10)
seeds = st.integers(min_value=0, max_value=2**31 - 1)


def _setup(seed, d=10, m=64, b=8):
    cfg = optics.DEFAULT_OPU
    bre, bim = optics.make_medium(jax.random.PRNGKey(seed), d, m)
    r = np.random.default_rng(seed)
    et = r.integers(-1, 2, size=(b, d)).astype(np.float32)
    n1 = r.normal(size=(b, cfg.npix(m))).astype(np.float32)
    n2 = r.normal(size=(b, cfg.npix(m))).astype(np.float32)
    return cfg, bre, bim, et, n1, n2


class TestRecovery:
    @hypothesis.given(seed=seeds)
    @hypothesis.settings(**SETTINGS)
    def test_noiseless_recovery_is_adc_limited(self, seed):
        cfg, bre, bim, et, n1, n2 = _setup(seed)
        p1e, p2e = optics.project_exact(et, bre, bim)
        p1, p2 = optics.opu_project(et, bre, bim, n1 * 0, n2 * 0,
                                    1e9, 0.0, cfg)
        lsb = cfg.gain_for(et.shape[1]) / (4 * cfg.amp)
        assert np.max(np.abs(np.asarray(p1) - np.asarray(p1e))) <= 1.5 * lsb
        assert np.max(np.abs(np.asarray(p2) - np.asarray(p2e))) <= 1.5 * lsb

    def test_noise_increases_with_less_photons(self):
        cfg, bre, bim, et, n1, n2 = _setup(0)
        p1e, _ = optics.project_exact(et, bre, bim)

        def err(n_ph):
            p1, _ = optics.opu_project(et, bre, bim, n1, n2, n_ph, 0.0, cfg)
            return float(np.std(np.asarray(p1) - np.asarray(p1e)))

        assert err(10.0) > err(1000.0)

    def test_quadratures_are_independent_projections(self):
        """Re/Im parts come from independent matrices — correlation ≈ 0."""
        cfg, bre, bim, et, n1, n2 = _setup(1, m=512, b=16)
        p1, p2 = optics.project_exact(et, bre, bim)
        p1 = np.asarray(p1).ravel()
        p2 = np.asarray(p2).ravel()
        corr = np.corrcoef(p1, p2)[0, 1]
        assert abs(corr) < 0.1

    def test_fft_demod_agrees_with_quadrature(self):
        """Textbook Fourier side-band filter ≈ quadrature demod.

        The FFT path has inherent macropixel truncation error (hard LPF
        on a blocky signal), so agreement is correlation-level, not
        allclose — see optics.py docstring.
        """
        cfg, bre, bim, et, n1, n2 = _setup(2, m=128)
        from compile.kernels import camera_intensity

        yre = et @ np.asarray(bre)
        yim = et @ np.asarray(bim)
        yre_pix = np.repeat(yre, 4, axis=1)
        yim_pix = np.repeat(yim, 4, axis=1)
        cosk, sink = optics.carrier_tables(cfg, 128)
        gain = cfg.gain_for(et.shape[1])
        counts = camera_intensity(yre_pix, yim_pix, cosk, sink,
                                  n1 * 0, n2 * 0, 1e9, 0.0,
                                  amp=cfg.amp, adc_gain=gain)
        q1, q2 = optics.demod_quadrature(counts, cfg, 128, gain)
        f1, f2 = optics.demod_fft(counts, cfg, 128, gain)
        for q, f in ((q1, f1), (q2, f2)):
            q = np.asarray(q).ravel()
            f = np.asarray(f).ravel()
            assert np.corrcoef(q, f)[0, 1] > 0.95

    @hypothesis.given(seed=seeds)
    @hypothesis.settings(**SETTINGS)
    def test_medium_is_unit_variance(self, seed):
        bre, bim = optics.make_medium(jax.random.PRNGKey(seed), 100, 100)
        power = np.asarray(bre) ** 2 + np.asarray(bim) ** 2
        assert abs(power.mean() - 1.0) < 0.1

    def test_saturation_is_rare_at_design_gain(self):
        cfg, bre, bim, et, n1, n2 = _setup(4, m=512, b=16)
        from compile.kernels import camera_intensity

        yre = np.repeat(et @ np.asarray(bre), 4, axis=1)
        yim = np.repeat(et @ np.asarray(bim), 4, axis=1)
        cosk, sink = optics.carrier_tables(cfg, 512)
        counts = np.asarray(camera_intensity(
            yre, yim, cosk, sink, n1, n2, cfg.n_ph, cfg.read_sigma,
            amp=cfg.amp, adc_gain=cfg.gain_for(et.shape[1])))
        assert (counts >= 255).mean() < 1e-3
