"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

hypothesis sweeps shapes (including non-block-multiple, tiny and skewed
ones) and value regimes; agreement is required to float32 accumulation
tolerance.  These tests are the core correctness signal for everything
the rust hot path executes.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import kernels
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(deadline=None, max_examples=12,
                suppress_health_check=[hypothesis.HealthCheck.too_slow])


def _rng(seed):
    return np.random.default_rng(seed)


dims = st.integers(min_value=1, max_value=200)
seeds = st.integers(min_value=0, max_value=2**31 - 1)


class TestMatmul:
    @hypothesis.given(m=dims, k=dims, n=dims, seed=seeds)
    @hypothesis.settings(**SETTINGS)
    def test_matches_ref(self, m, k, n, seed):
        r = _rng(seed)
        x = r.normal(size=(m, k)).astype(np.float32)
        y = r.normal(size=(k, n)).astype(np.float32)
        got = kernels.matmul(x, y)
        want = ref.matmul(jnp.asarray(x), jnp.asarray(y))
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_paper_shapes(self):
        """The exact shapes on the paper's hot path."""
        r = _rng(0)
        for (m, k, n) in [(128, 784, 1024), (128, 1024, 1024),
                          (128, 1024, 10), (128, 10, 1024)]:
            x = r.normal(size=(m, k)).astype(np.float32)
            y = r.normal(size=(k, n)).astype(np.float32)
            np.testing.assert_allclose(
                kernels.matmul(x, y), ref.matmul(jnp.asarray(x), jnp.asarray(y)),
                rtol=5e-4, atol=5e-4)

    def test_zero_padding_exact(self):
        """Padding lanes must contribute exactly zero."""
        r = _rng(1)
        x = r.normal(size=(3, 5)).astype(np.float32)
        y = r.normal(size=(5, 7)).astype(np.float32)
        np.testing.assert_allclose(kernels.matmul(x, y), x @ y,
                                   rtol=1e-5, atol=1e-5)


class TestDfaGrads:
    @hypothesis.given(b=st.integers(1, 64), fi=dims, u=dims, seed=seeds)
    @hypothesis.settings(**SETTINGS)
    def test_matches_ref(self, b, fi, u, seed):
        r = _rng(seed)
        hprev = r.normal(size=(b, fi)).astype(np.float32)
        p = r.normal(size=(b, u)).astype(np.float32)
        h = np.tanh(r.normal(size=(b, u))).astype(np.float32)
        dw, db = kernels.dfa_grads(hprev, p, h)
        dw2, db2 = ref.dfa_grads(jnp.asarray(hprev), jnp.asarray(p),
                                 jnp.asarray(h))
        np.testing.assert_allclose(dw, dw2, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(db, db2, rtol=2e-4, atol=2e-4)

    def test_gate_is_tanh_derivative(self):
        """With hprev = identity rows, δW recovers the gated error."""
        b = 4
        u = 3
        hprev = np.eye(b, dtype=np.float32)  # [B, B]
        p = _rng(2).normal(size=(b, u)).astype(np.float32)
        h = np.tanh(_rng(3).normal(size=(b, u))).astype(np.float32)
        dw, _ = kernels.dfa_grads(hprev, p, h)
        np.testing.assert_allclose(dw, p * (1 - h * h), rtol=1e-5, atol=1e-6)


class TestAdam:
    @hypothesis.given(rows=dims, cols=dims, t=st.integers(1, 10_000),
                      seed=seeds)
    @hypothesis.settings(**SETTINGS)
    def test_matches_ref(self, rows, cols, t, seed):
        r = _rng(seed)
        p = r.normal(size=(rows, cols)).astype(np.float32)
        g = r.normal(size=(rows, cols)).astype(np.float32)
        m = r.normal(size=(rows, cols)).astype(np.float32) * 0.1
        v = np.abs(r.normal(size=(rows, cols))).astype(np.float32) * 0.01
        got = kernels.adam_update(p, g, m, v, float(t), 0.01)
        want = ref.adam_update(jnp.asarray(p), jnp.asarray(g),
                               jnp.asarray(m), jnp.asarray(v), float(t), 0.01)
        for a, b in zip(got, want):
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)

    def test_vector_param(self):
        """1-D parameters (biases) round-trip through the 2-D layout."""
        r = _rng(7)
        p = r.normal(size=(1024,)).astype(np.float32)
        g = r.normal(size=(1024,)).astype(np.float32)
        z = np.zeros_like(p)
        got = kernels.adam_update(p, g, z, z, 1.0, 0.001)
        want = ref.adam_update(*(jnp.asarray(a) for a in (p, g, z, z)),
                               1.0, 0.001)
        for a, b in zip(got, want):
            assert a.shape == (1024,)
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)

    def test_first_step_is_lr_signed_grad(self):
        """At t=1 with zero moments, Adam steps ≈ -lr·sign(g)."""
        g = np.array([[3.0, -2.0, 0.5]], dtype=np.float32)
        p = np.zeros_like(g)
        z = np.zeros_like(g)
        p2, _, _ = kernels.adam_update(p, g, z, z, 1.0, 0.01)
        np.testing.assert_allclose(p2, -0.01 * np.sign(g), rtol=1e-3)


class TestTernary:
    @hypothesis.given(b=st.integers(1, 64), d=dims,
                      th=st.floats(0.0, 1.0), seed=seeds)
    @hypothesis.settings(**SETTINGS)
    def test_matches_ref(self, b, d, th, seed):
        x = _rng(seed).normal(size=(b, d)).astype(np.float32)
        got = kernels.ternarize(x, th)
        want = ref.ternarize(jnp.asarray(x), th)
        np.testing.assert_allclose(got, want)

    @hypothesis.given(b=st.integers(1, 16), d=st.integers(1, 32), seed=seeds)
    @hypothesis.settings(**SETTINGS)
    def test_values_are_ternary(self, b, d, seed):
        x = _rng(seed).normal(size=(b, d)).astype(np.float32)
        out = np.asarray(kernels.ternarize(x, 0.1))
        assert set(np.unique(out)).issubset({-1.0, 0.0, 1.0})

    def test_paper_eq4(self):
        x = np.array([[0.2, 0.05, -0.05, -0.2, 0.1, -0.1]], np.float32)
        out = np.asarray(kernels.ternarize(x, 0.1))
        # strict inequalities at ±θ: 0.1 and -0.1 map to 0
        np.testing.assert_array_equal(out, [[1, 0, 0, -1, 0, 0]])

    def test_idempotent(self):
        x = _rng(0).normal(size=(8, 10)).astype(np.float32)
        once = kernels.ternarize(x, 0.1)
        twice = kernels.ternarize(np.asarray(once), 0.5)
        np.testing.assert_array_equal(np.asarray(once), np.asarray(twice))


class TestIntensity:
    @hypothesis.given(b=st.integers(1, 8), m=st.integers(1, 64), seed=seeds)
    @hypothesis.settings(**SETTINGS)
    def test_matches_ref(self, b, m, seed):
        r = _rng(seed)
        npix = 4 * m
        yre = r.normal(size=(b, npix)).astype(np.float32)
        yim = r.normal(size=(b, npix)).astype(np.float32)
        px = np.arange(npix)
        cosk = np.cos(np.pi / 2 * px).astype(np.float32)[None]
        sink = np.sin(np.pi / 2 * px).astype(np.float32)[None]
        n1 = r.normal(size=(b, npix)).astype(np.float32)
        n2 = r.normal(size=(b, npix)).astype(np.float32)
        kw = dict(amp=16.0, adc_gain=2.0)
        got = kernels.camera_intensity(yre, yim, cosk, sink, n1, n2,
                                       100.0, 2.0, **kw)
        want = ref.camera_intensity(
            jnp.asarray(yre), jnp.asarray(yim), jnp.asarray(cosk),
            jnp.asarray(sink), jnp.asarray(n1), jnp.asarray(n2),
            100.0, 2.0, **kw)
        # round() at a half-integer boundary may differ by 1 count
        assert np.max(np.abs(np.asarray(got) - np.asarray(want))) <= 1.0

    def test_range_and_quantization(self):
        r = _rng(3)
        npix = 64
        yre = (r.normal(size=(2, npix)) * 50).astype(np.float32)
        yim = (r.normal(size=(2, npix)) * 50).astype(np.float32)
        z = np.zeros((2, npix), np.float32)
        px = np.arange(npix)
        cosk = np.cos(np.pi / 2 * px).astype(np.float32)[None]
        sink = np.sin(np.pi / 2 * px).astype(np.float32)[None]
        out = np.asarray(kernels.camera_intensity(
            yre, yim, cosk, sink, z, z, 1e9, 0.0, amp=16.0, adc_gain=2.0))
        assert out.min() >= 0.0 and out.max() <= 255.0
        np.testing.assert_array_equal(out, np.round(out))
