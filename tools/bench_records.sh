#!/usr/bin/env bash
# Produce the committed bench records: run the e6 streaming, e4 scaling
# and e7 loadgen benches plus the chaos soak in release mode and collect
# every JSON record line they print (compact objects containing a
# "bench" key: e6_genkernel / e6_streaming / e6_tile_cache /
# e6_cache_contention, e4_shard_sweep / e4_service_sweep /
# e4_hetero_sweep, e7_loadgen, chaos) into BENCH_e6.json /
# BENCH_e4.json / BENCH_e7.json / BENCH_chaos.json at the repo root as
# JSON arrays.
#
# Usage: tools/bench_records.sh            (from anywhere in the repo)
#
# The CI `bench-records` job runs this and uploads the two files as
# artifacts; committing refreshed copies alongside a perf-relevant PR is
# what keeps the perf trajectory a recorded fact instead of a claim.
set -euo pipefail
cd "$(dirname "$0")/.."

collect() {
    local bench="$1" out="$2"
    local log
    log=$(mktemp)
    echo "== running $bench (release) =="
    cargo bench --bench "$bench" | tee "$log"
    # Record lines are single compact JSON objects containing a "bench"
    # key (Json::Obj is a BTreeMap, so keys serialize alphabetically —
    # the line does NOT necessarily start with {"bench").
    {
        echo '['
        grep '^{.*"bench":' "$log" | sed '$!s/$/,/'
        echo ']'
    } >"$out"
    rm -f "$log"
    echo "wrote $out"
}

collect e6_streaming BENCH_e6.json
collect e4_scaling BENCH_e4.json
collect e7_loadgen BENCH_e7.json

# The chaos soak is a test, not a bench, but its headline case prints
# the same kind of compact record ({"bench":"chaos",...} — injected
# fault / resume / replay counts next to the bitwise verdict).
collect_test() {
    local test="$1" out="$2"
    local log
    log=$(mktemp)
    echo "== running $test test (release) =="
    cargo test --release -q --test "$test" -- --nocapture | tee "$log"
    {
        echo '['
        grep '^{.*"bench":' "$log" | sed '$!s/$/,/'
        echo ']'
    } >"$out"
    rm -f "$log"
    echo "wrote $out"
}

collect_test chaos BENCH_chaos.json

# Telemetry artifacts ride along with the perf records: a traced
# heterogeneous training run (tests/trace_spans.rs, `--ignored` export
# smoke) writes the Chrome trace + Prometheus dump next to the BENCH
# files so a perf PR carries the timeline that explains its numbers.
echo "== running trace smoke export (release) =="
TRACE_SMOKE_TRACE_OUT=TRACE_smoke.json \
TRACE_SMOKE_METRICS_OUT=METRICS_smoke.prom \
    cargo test --release -q --test trace_spans -- --ignored trace_smoke_export
echo "wrote TRACE_smoke.json METRICS_smoke.prom"
