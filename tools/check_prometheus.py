#!/usr/bin/env python3
"""Validate a Prometheus text-exposition dump (CI `trace-smoke` job).

Checks, line by line, what `metrics::export::prometheus_text` promises:

  * every ``# TYPE name kind`` line is unique (the exporter's collision
    guard means a name is emitted at most once);
  * every sample parses as ``name[{le="..."}] value`` with a finite
    value (the exporter zeroes NaN/inf before writing);
  * histogram buckets are cumulative-monotone with sorted finite ``le``
    bounds, the ``+Inf`` bucket comes last, and ``<name>_count`` equals
    the ``+Inf`` bucket count;
  * every metric TYPEd as a histogram actually has bucket lines.

Usage: tools/check_prometheus.py FILE

Stdlib only, same policy as python/tests (no third-party packages).
"""

import math
import re
import sys

SAMPLE = re.compile(
    r'^(?P<name>[A-Za-z_:][A-Za-z0-9_:]*)'
    r'(\{(?P<labels>[^}]*)\})?\s+(?P<value>\S+)$'
)


def fail(msg):
    sys.exit(f"check_prometheus: {msg}")


def main(path):
    types = {}  # metric name -> kind
    samples = {}  # unlabeled sample name -> value
    buckets = {}  # histogram name -> [(le_label, count)] in file order
    with open(path) as f:
        lines = f.read().splitlines()
    for ln, line in enumerate(lines, 1):
        if not line.strip():
            continue
        if line.startswith("# TYPE "):
            parts = line.split()
            if len(parts) != 4:
                fail(f"{path}:{ln}: malformed TYPE line: {line!r}")
            name, kind = parts[2], parts[3]
            if name in types:
                fail(f"{path}:{ln}: duplicate TYPE for {name}")
            types[name] = kind
            continue
        if line.startswith("#"):
            continue
        m = SAMPLE.match(line)
        if not m:
            fail(f"{path}:{ln}: unparseable sample: {line!r}")
        name, labels = m.group("name"), m.group("labels")
        try:
            value = float(m.group("value"))
        except ValueError:
            fail(f"{path}:{ln}: non-numeric value: {line!r}")
        if not math.isfinite(value):
            fail(f"{path}:{ln}: non-finite value: {line!r}")
        if name.endswith("_bucket"):
            if not (labels and labels.startswith('le="') and labels.endswith('"')):
                fail(f"{path}:{ln}: bucket without an le label: {line!r}")
            hist = name[: -len("_bucket")]
            buckets.setdefault(hist, []).append((labels[4:-1], value))
        else:
            if labels:
                fail(f"{path}:{ln}: unexpected labels: {line!r}")
            if name in samples:
                fail(f"{path}:{ln}: duplicate sample name {name}")
            samples[name] = value
    for hist, bs in buckets.items():
        if types.get(hist) != "histogram":
            fail(f"{path}: buckets for {hist} but no histogram TYPE")
        les = [le for le, _ in bs]
        counts = [c for _, c in bs]
        if les[-1] != "+Inf":
            fail(f"{path}: {hist}: last bucket is le={les[-1]!r}, not +Inf")
        if "+Inf" in les[:-1]:
            fail(f"{path}: {hist}: multiple +Inf buckets")
        bounds = [float(le) for le in les[:-1]]
        if any(b <= a for a, b in zip(bounds, bounds[1:])):
            fail(f"{path}: {hist}: le bounds not strictly sorted: {les}")
        if any(b < a for a, b in zip(counts, counts[1:])):
            fail(f"{path}: {hist}: bucket counts not monotone: {counts}")
        if samples.get(f"{hist}_count") != counts[-1]:
            fail(f"{path}: {hist}_count != +Inf bucket count")
        if f"{hist}_sum" not in samples:
            fail(f"{path}: {hist}_sum missing")
    for name, kind in types.items():
        if kind == "histogram" and name not in buckets:
            fail(f"{path}: histogram {name} has no bucket lines")
    print(f"{path}: OK — {len(types)} metrics, {len(buckets)} histograms")


if __name__ == "__main__":
    if len(sys.argv) != 2:
        fail("usage: check_prometheus.py FILE")
    main(sys.argv[1])
